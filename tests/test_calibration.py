"""Calibration-loop differential/property tier (repro.core.calibration).

What this pins, in four layers:

* **accumulator exactness** — the Welford (count, mean, m2) statistics match
  the stdlib ``statistics`` module at tolerance on random data and are
  *bitwise* exact on identical samples (mean stays the sample, m2 stays 0.0)
  — the property the sigma=0 contract stands on;
* **round-trip properties** — ledger → MeasuredCostTable → JSON → table is
  fingerprint-stable, dump_json's deterministic (rid, cycle) row order makes
  calibration fingerprints independent of request interleaving, and
  tampered/mis-versioned files fail loudly;
* **sigma=0 bit-identity differentials** — a measured table whose samples
  match the analytical model materializes the analytical CostModel *object*
  itself, so solves through every backend (numpy / scan / pallas) are
  bit-identical to the analytical path on every smoke config;
* **uncertainty semantics** — confidence pricing (mean + z·sigma) is
  monotone: higher confidence never yields fewer bursts, never a lower
  Q_min, never a lower E_total; and a crash-schedule soak checks the
  headline guarantee — a confidence-c plan completes within budget on ≥ c
  of perturbed-draw replays.

The property checks run under stdlib-``random`` seeded drivers always, and
additionally under hypothesis when it is installed (the test_partition.py
idiom — the seed container has no hypothesis, CI may).
"""

import json
import math
import random
import statistics

import numpy as np
import pytest

from helpers_random import random_cost_model, random_q_grid, random_task_graph

from repro.api import (
    CalibrationError,
    MeasuredCostTable,
    PartitionSpec,
    SpecError,
    clear_measured_defaults,
    install_measured_default,
    solve,
    use_measured,
)
from repro.configs import SMOKE_CONFIGS
from repro.core import lower_config, q_min
from repro.core.calibration import (
    CALIBRATION_VERSION,
    CATEGORIES as CAL_CATEGORIES,
    KernelStats,
    measured_default,
    z_score,
)
from repro.core.cost import CostModel, LinearTransfer, cost_scalars
from repro.core.layer_profile import analytical_cost_model, default_cost_model
from repro.core.partition import Infeasible
from repro.obs.ledger import CATEGORIES, EnergyLedger

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

ARCHS = sorted(SMOKE_CONFIGS)


def _ledger_matching(cm: CostModel, n_requests: int = 3, n_cycles: int = 4,
                     commit: float = 0.0) -> EnergyLedger:
    """A ledger whose restore rows are exactly the model's e_startup — what a
    run that matched the analytical model would have captured."""
    led = EnergyLedger()
    for rid in range(n_requests):
        for c in range(n_cycles):
            led.charge(rid, c, restore=float(cm.e_startup), compute=0.25,
                       commit=commit, vt=float(rid + c))
    return led


def _stats_table(base: CostModel, *, restore=(), commit=(), compute=(),
                 kind: str = "time") -> MeasuredCostTable:
    mt = MeasuredCostTable(base, kind)
    for x in restore:
        mt.add("restore", x)
    for x in commit:
        mt.add("commit", x)
    for x in compute:
        mt.add("compute", x)
    return mt


# ---------------------------------------------------------------------------
# z-score and Welford accumulator
# ---------------------------------------------------------------------------


def test_z_score_median_and_none_are_exact_zero():
    assert z_score(None) == 0.0
    assert z_score(0.5) == 0.0  # exactly, no inv_cdf rounding residue


def test_z_score_matches_normal_quantiles():
    assert z_score(0.975) == pytest.approx(1.959964, abs=1e-5)
    assert z_score(0.841344746) == pytest.approx(1.0, abs=1e-6)
    assert z_score(0.99) == pytest.approx(2.326348, abs=1e-5)
    # symmetric: sub-median confidence discounts
    assert z_score(0.3) == pytest.approx(-z_score(0.7), abs=1e-12)


@pytest.mark.parametrize("bad", [0.0, 1.0, -0.25, 2.0, float("nan")])
def test_z_score_rejects_out_of_range(bad):
    with pytest.raises(CalibrationError):
        z_score(bad)


def test_kernel_stats_matches_statistics_module():
    rng = random.Random(7)
    for _ in range(20):
        xs = [rng.uniform(1e-6, 10.0) for _ in range(rng.randint(1, 60))]
        s = KernelStats()
        for x in xs:
            s.add(x)
        assert s.count == len(xs)
        assert s.mean == pytest.approx(statistics.fmean(xs), rel=1e-12)
        assert s.variance == pytest.approx(statistics.pvariance(xs),
                                           rel=1e-9, abs=1e-18)
        assert s.std == pytest.approx(math.sqrt(s.variance))


def test_kernel_stats_identical_samples_bit_exact():
    """Welford keeps the mean bitwise equal to x over identical samples
    (delta == 0.0 on every update) and m2 exactly 0.0 — a naive sum/n would
    round. This is the foundation of the sigma=0 bit-identity contract."""
    for x in (0.1, 1e-5, 3.7, 9e-6, 2.0 ** -37):
        s = KernelStats()
        for _ in range(137):
            s.add(x)
        assert s.mean == x  # bitwise, not approx
        assert s.m2 == 0.0
        assert s.std == 0.0
        assert s.cv == 0.0


def test_kernel_stats_rejects_non_finite():
    s = KernelStats()
    for bad in (float("nan"), float("inf"), float("-inf")):
        with pytest.raises(CalibrationError):
            s.add(bad)


def test_calibration_categories_agree_with_ledger():
    assert tuple(CAL_CATEGORIES) == tuple(CATEGORIES)


# ---------------------------------------------------------------------------
# Ingestion and round-trip properties
# ---------------------------------------------------------------------------


def test_from_ledger_counts_and_means():
    cm = analytical_cost_model("time")
    led = _ledger_matching(cm, n_requests=2, n_cycles=3)
    led.overhead(0, 1, 0.5)
    mt = MeasuredCostTable.from_ledger(led, base=cm, kind="time")
    assert mt.stats["restore"].count == 6
    assert mt.stats["restore"].mean == float(cm.e_startup)
    assert mt.stats["compute"].count == 6
    assert mt.stats["commit"].count == 0  # zero commits produce no rows
    assert mt.stats["replay"].count == 1
    assert mt.stats["replay"].mean == 0.5
    assert mt.n_samples == 13


def test_ingest_rejects_unknown_category_and_malformed_rows():
    mt = MeasuredCostTable(analytical_cost_model("time"))
    with pytest.raises(CalibrationError):
        mt.add("warp-drive", 1.0)
    with pytest.raises(CalibrationError):
        mt.ingest_rows([{"energy": 1.0}])  # no category
    with pytest.raises(CalibrationError):
        mt.ingest_rows([3.14])  # not a row at all


def test_base_must_be_cost_model():
    with pytest.raises(CalibrationError):
        MeasuredCostTable("tpu-host-offload")


def test_table_json_round_trip_bitwise(tmp_path):
    rng = random.Random(11)
    mt = _stats_table(
        random_cost_model(rng),
        restore=[rng.uniform(0.01, 1.0) for _ in range(9)],
        commit=[rng.uniform(0.001, 0.1) for _ in range(5)],
        compute=[rng.uniform(0.1, 2.0) for _ in range(7)],
    )
    path = tmp_path / "calib.json"
    mt.to_json(str(path), source="unit-test")
    back = MeasuredCostTable.from_json(str(path))
    assert back.fingerprint() == mt.fingerprint()
    for cat in CAL_CATEGORIES:
        assert back.stats[cat].count == mt.stats[cat].count
        assert back.stats[cat].mean == mt.stats[cat].mean  # bitwise
        assert back.stats[cat].m2 == mt.stats[cat].m2
    assert back.meta["source"] == "unit-test"
    assert np.array_equal(cost_scalars(back.base), cost_scalars(mt.base))


def test_ledger_dump_round_trip_preserves_fingerprint(tmp_path):
    cm = analytical_cost_model("time")
    led = _ledger_matching(cm, commit=1e-6)
    direct = MeasuredCostTable.from_ledger(led, base=cm)
    path = tmp_path / "ledger.json"
    led.dump_json(str(path), kind="time", arch="unit")
    via_file = MeasuredCostTable.from_ledger_json(str(path), base=cm)
    assert via_file.kind == "time"
    assert via_file.fingerprint() == direct.fingerprint()
    assert via_file.meta["arch"] == "unit"


def test_from_json_rejects_version_mismatch(tmp_path):
    mt = MeasuredCostTable(analytical_cost_model("time"))
    payload = mt.to_payload()
    payload["version"] = CALIBRATION_VERSION + 1
    path = tmp_path / "calib.json"
    path.write_text(json.dumps(payload))
    with pytest.raises(CalibrationError, match="version"):
        MeasuredCostTable.from_json(str(path))


def test_from_json_rejects_tampered_stats(tmp_path):
    mt = _stats_table(analytical_cost_model("time"), restore=[1e-5, 2e-5])
    payload = mt.to_payload()
    payload["stats"]["restore"]["mean"] = 5e-5  # edited by hand
    path = tmp_path / "calib.json"
    path.write_text(json.dumps(payload))
    with pytest.raises(CalibrationError, match="fingerprint"):
        MeasuredCostTable.from_json(str(path))


def _fingerprint_free_payload(**corrupt) -> dict:
    """A to_payload dict with the fingerprint key *deleted* and the restore
    stats entry overridden — the load path skips the fingerprint check when
    the key is absent, so these corruptions used to sail straight through
    into confidence pricing."""
    mt = _stats_table(analytical_cost_model("time"), restore=[1e-5, 2e-5])
    payload = mt.to_payload()
    del payload["fingerprint"]
    payload["stats"]["restore"].update(corrupt)
    return payload


def test_fingerprint_free_payload_loads_clean():
    """Sanity: deleting the fingerprint alone is legitimate (hand-authored
    tables) and must keep loading."""
    back = MeasuredCostTable.from_payload(_fingerprint_free_payload())
    assert back.stats["restore"].count == 2


@pytest.mark.parametrize(
    "corrupt, match",
    [
        ({"mean": float("nan")}, "non-finite"),
        ({"mean": float("inf")}, "non-finite"),
        ({"m2": float("-inf")}, "non-finite"),
        ({"count": -3}, "negative count"),
        ({"m2": -1e-9}, "negative m2"),
        ({"count": 0}, "zero samples"),  # mean/m2 stay non-zero
        ({"mean": "fast"}, "malformed"),
        ({"count": None}, "malformed"),
    ],
)
def test_load_rejects_invalid_stats_without_fingerprint(corrupt, match):
    """Welford invariants are enforced on load even when the fingerprint
    check cannot fire: NaN/inf moments, negative counts, negative variance
    accumulators, and zero-sample entries with non-zero moments all raise
    the typed CalibrationError."""
    with pytest.raises(CalibrationError, match=match):
        MeasuredCostTable.from_payload(_fingerprint_free_payload(**corrupt))


def test_load_rejects_missing_stats_field():
    payload = _fingerprint_free_payload()
    del payload["stats"]["restore"]["m2"]
    with pytest.raises(CalibrationError, match="malformed"):
        MeasuredCostTable.from_payload(payload)


def test_from_json_rejects_nan_mean_on_disk(tmp_path):
    """End-to-end through the file loader: json serializes NaN as the
    non-standard ``NaN`` literal, python's json reads it back, and from_json
    must still refuse it."""
    payload = _fingerprint_free_payload(mean=float("nan"))
    path = tmp_path / "nan.json"
    path.write_text(json.dumps(payload))
    with pytest.raises(CalibrationError, match="non-finite"):
        MeasuredCostTable.from_json(str(path))


def test_from_ledger_json_rejects_non_ledger(tmp_path):
    path = tmp_path / "not_a_ledger.json"
    path.write_text(json.dumps({"rows": []}))
    with pytest.raises(CalibrationError):
        MeasuredCostTable.from_ledger_json(str(path))


def _interleaved_ledgers(rng: random.Random):
    """Two ledgers with the same per-(rid, cycle) charges appended in
    different interleavings (the traffic harness's continuation batching
    commits many requests' cycles in schedule-dependent order)."""
    charges = []
    for rid in range(rng.randint(2, 4)):
        for cycle in range(rng.randint(1, 5)):
            charges.append((rid, cycle, rng.uniform(0.01, 1.0),
                            rng.uniform(0.0, 2.0), rng.uniform(0.0, 0.5)))
    a, b = EnergyLedger(), EnergyLedger()
    for rid, cycle, restore, compute, commit in charges:
        a.charge(rid, cycle, restore=restore, compute=compute, commit=commit)
    rng.shuffle(charges)
    for rid, cycle, restore, compute, commit in charges:
        b.charge(rid, cycle, restore=restore, compute=compute, commit=commit)
    return a, b


def test_dump_json_interleaving_invariant_fingerprint(tmp_path):
    """Satellite: deterministic (rid, cycle) export order ⇒ the calibration
    fingerprint built from a dumped ledger is a function of *what was
    charged*, not of the schedule that charged it."""
    cm = analytical_cost_model("time")
    for seed in range(6):
        a, b = _interleaved_ledgers(random.Random(seed))
        pa, pb = tmp_path / f"a{seed}.json", tmp_path / f"b{seed}.json"
        a.dump_json(str(pa))
        b.dump_json(str(pb))
        ra = json.loads(pa.read_text())["entries"]
        rb = json.loads(pb.read_text())["entries"]
        assert ra == rb
        fa = MeasuredCostTable.from_ledger_json(str(pa), base=cm).fingerprint()
        fb = MeasuredCostTable.from_ledger_json(str(pb), base=cm).fingerprint()
        assert fa == fb


def test_fingerprint_sensitive_to_stats_kind_and_base():
    cm = analytical_cost_model("time")
    base_fp = _stats_table(cm, restore=[1e-5]).fingerprint()
    assert _stats_table(cm, restore=[2e-5]).fingerprint() != base_fp
    assert _stats_table(cm, restore=[1e-5],
                        kind="memory").fingerprint() != base_fp
    other = CostModel(e_startup=2e-5, read=cm.read, write=cm.write,
                      name=cm.name)
    assert _stats_table(other, restore=[1e-5]).fingerprint() != base_fp


# ---------------------------------------------------------------------------
# CostModel materialization
# ---------------------------------------------------------------------------


def test_clean_round_trip_returns_base_object():
    """The bit-identity lever: samples matching the model ⇒ cost_model()
    IS the base CostModel (same object — same name, same fingerprint, same
    solves), at any confidence (zero variance prices nothing)."""
    cm = analytical_cost_model("time")
    mt = MeasuredCostTable.from_ledger(_ledger_matching(cm), base=cm)
    assert mt.cost_model() is cm
    assert mt.cost_model(0.5) is cm
    assert mt.cost_model(0.999) is cm


def test_no_samples_returns_base_object():
    cm = analytical_cost_model("time")
    assert MeasuredCostTable(cm).cost_model() is cm
    assert MeasuredCostTable(cm).cost_model(0.9) is cm


def test_drifted_mean_reprices_e_startup():
    cm = analytical_cost_model("time")
    mt = _stats_table(cm, restore=[2e-5, 3e-5])
    priced = mt.cost_model()
    assert priced is not cm
    assert priced.e_startup == mt.stats["restore"].mean  # bitwise
    assert priced.name == cm.name + "+measured"
    # transfers untouched without commit samples
    assert priced.read.c0 == cm.read.c0 and priced.write.c1 == cm.write.c1


def test_confidence_prices_mean_plus_z_sigma():
    cm = analytical_cost_model("time")
    mt = _stats_table(cm, restore=[1e-5, 2e-5, 3e-5, 4e-5])
    r = mt.stats["restore"]
    priced = mt.cost_model(0.975)
    assert priced.e_startup == r.mean + z_score(0.975) * r.std  # bitwise
    assert "@0.975" in priced.name
    # sub-median confidence discounts below the mean
    assert mt.cost_model(0.3).e_startup < r.mean


def test_commit_noise_scales_transfer_curves():
    cm = analytical_cost_model("time")
    mt = _stats_table(cm, commit=[1e-6, 2e-6, 3e-6])
    s = mt.stats["commit"]
    scale = 1.0 + z_score(0.9) * (s.std / s.mean)
    priced = mt.cost_model(0.9)
    assert priced.read.c0 == cm.read.c0 * scale  # bitwise
    assert priced.read.c1 == cm.read.c1 * scale
    assert priced.write.c0 == cm.write.c0 * scale
    assert priced.e_startup == cm.e_startup  # no restore samples
    # at the mean (z=0) commit noise prices nothing
    assert mt.transfer_scale() == 1.0
    assert mt.cost_model() is cm


def test_e_startup_and_scale_monotone_in_confidence():
    rng = random.Random(3)
    mt = _stats_table(
        analytical_cost_model("time"),
        restore=[rng.uniform(1e-5, 3e-5) for _ in range(30)],
        commit=[rng.uniform(1e-6, 4e-6) for _ in range(30)],
    )
    confidences = [0.5, 0.6, 0.75, 0.9, 0.975, 0.999]
    e = [mt.e_startup(c) for c in confidences]
    s = [mt.transfer_scale(c) for c in confidences]
    assert e == sorted(e) and len(set(e)) == len(e)
    assert s == sorted(s) and len(set(s)) == len(s)


# ---------------------------------------------------------------------------
# PartitionSpec / Engine threading
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bad", [0.0, 1.0, -0.5, 2.0, float("nan"), "high"])
def test_spec_confidence_validation(bad):
    with pytest.raises(SpecError, match="confidence"):
        PartitionSpec(config="qwen3-4b", shapes=((2, 16),), smoke=True,
                      confidence=bad)


def test_spec_rejects_non_cost_cost():
    with pytest.raises(SpecError, match="cost="):
        PartitionSpec(config="qwen3-4b", shapes=((2, 16),), smoke=True,
                      cost=object())


def test_confidence_with_plain_cost_model_is_typed_error():
    rng = random.Random(0)
    g, cm = random_task_graph(rng), random_cost_model(rng)
    with pytest.raises(SpecError, match="confidence"):
        solve(PartitionSpec(graph=g, cost=cm, confidence=0.9,
                            backend="numpy"))


def test_solve_accepts_measured_table_as_cost():
    rng = random.Random(1)
    g, cm = random_task_graph(rng), random_cost_model(rng)
    mt = _stats_table(cm)  # no samples → base pass-through
    a = solve(PartitionSpec(graph=g, cost=cm, backend="numpy")).partition()
    b = solve(PartitionSpec(graph=g, cost=mt, backend="numpy")).partition()
    assert a.e_total == b.e_total and a.bounds == b.bounds


def test_measured_default_registry_and_scoping():
    cm = analytical_cost_model("time")
    drifted = _stats_table(cm, restore=[5e-5, 7e-5])
    assert measured_default("time") is None
    try:
        install_measured_default(drifted)
        assert measured_default("time") is drifted
        assert default_cost_model("time").name == cm.name + "+measured"
    finally:
        clear_measured_defaults("time")
    assert measured_default("time") is None
    assert default_cost_model("time").name == cm.name
    # scoped variant restores the previous registration, even nested
    with use_measured(drifted):
        clean = MeasuredCostTable(cm)
        with use_measured(clean):
            assert measured_default("time") is clean
        assert measured_default("time") is drifted
    assert measured_default("time") is None
    with pytest.raises(CalibrationError):
        install_measured_default(cm)  # not a table


def test_installed_default_drives_config_specs():
    """An installed calibration is what config-lowered specs price with —
    including confidence=, with no explicit cost= needed."""
    cm = analytical_cost_model("time")
    drifted = _stats_table(cm, restore=[3e-5, 5e-5])
    spec = PartitionSpec(config="qwen3-4b", shapes=((2, 16),), smoke=True,
                         backend="scan")
    base_e = float(solve(spec).sweep.e_total[0])
    with use_measured(drifted):
        drift_e = float(solve(spec).sweep.e_total[0])
        conf = dataclasses_replace_confidence(spec, 0.975)
        conf_e = float(solve(conf).sweep.e_total[0])
    assert drift_e > base_e           # measured mean drifted upward
    assert conf_e > drift_e           # z·sigma on top of the mean
    assert float(solve(spec).sweep.e_total[0]) == base_e  # registry restored


def dataclasses_replace_confidence(spec, c):
    import dataclasses

    return dataclasses.replace(spec, confidence=c)


# ---------------------------------------------------------------------------
# sigma=0 bit-identity differentials: every smoke config × every backend
# ---------------------------------------------------------------------------


def _assert_sweeps_equal(a, b, ctx=""):
    assert a.n_tasks == b.n_tasks, ctx
    for field in ("dp", "parent", "e_total", "feasible", "starts"):
        assert getattr(a, field).tobytes() == getattr(b, field).tobytes(), \
            (ctx, field)


def _clean_table_for(cm: CostModel) -> MeasuredCostTable:
    mt = MeasuredCostTable.from_ledger(_ledger_matching(cm), base=cm)
    assert mt.cost_model() is cm  # precondition for the differentials
    return mt


@pytest.mark.parametrize("arch", ARCHS)
def test_sigma0_bit_identity_numpy(arch):
    cm = analytical_cost_model("time")
    mt = _clean_table_for(cm)
    g = lower_config(SMOKE_CONFIGS[arch], batch=2, seq=16, kind="time")
    for q in (q_min(g, cm), None):
        a = solve(PartitionSpec(graph=g, cost=cm, q_max=q,
                                backend="numpy")).partition()
        b = solve(PartitionSpec(graph=g, cost=mt, q_max=q, confidence=0.5,
                                backend="numpy")).partition()
        assert a.e_total == b.e_total and a.bounds == b.bounds, (arch, q)
    # infeasible Q raises identically through both cost sources
    for cost in (cm, mt):
        with pytest.raises(Infeasible):
            solve(PartitionSpec(graph=g, cost=cost, q_max=1e-12,
                                backend="numpy")).partition()


@pytest.mark.parametrize("arch", ARCHS)
def test_sigma0_bit_identity_scan(arch):
    cm = analytical_cost_model("time")
    mt = _clean_table_for(cm)
    g = lower_config(SMOKE_CONFIGS[arch], batch=2, seq=16, kind="time")
    qs = (1e-12, q_min(g, cm), None)
    a = solve(PartitionSpec(graph=g, cost=cm, q_grid=qs, backend="scan"))
    b = solve(PartitionSpec(graph=g, cost=mt, q_grid=qs, backend="scan"))
    _assert_sweeps_equal(a.sweep, b.sweep, arch)


@pytest.mark.slow
@pytest.mark.parametrize("arch", ARCHS)
def test_sigma0_bit_identity_pallas(arch):
    cm = analytical_cost_model("time")
    mt = _clean_table_for(cm)
    g = lower_config(SMOKE_CONFIGS[arch], batch=2, seq=16, kind="time")
    qs = (q_min(g, cm), None)
    a = solve(PartitionSpec(graph=g, cost=cm, q_grid=qs, backend="pallas"))
    b = solve(PartitionSpec(graph=g, cost=mt, q_grid=qs, backend="pallas"))
    _assert_sweeps_equal(a.sweep, b.sweep, arch)


def test_sigma0_bit_identity_pallas_smoke():
    """Fast-tier representative of the slow pallas matrix above."""
    cm = analytical_cost_model("time")
    mt = _clean_table_for(cm)
    g = lower_config(SMOKE_CONFIGS["qwen3-4b"], batch=2, seq=16, kind="time")
    qs = (q_min(g, cm), None)
    a = solve(PartitionSpec(graph=g, cost=cm, q_grid=qs, backend="pallas"))
    b = solve(PartitionSpec(graph=g, cost=mt, q_grid=qs, backend="pallas"))
    _assert_sweeps_equal(a.sweep, b.sweep)


def test_measured_scalars_differential_all_backends():
    """The non-trivial direction: a *drifted* table at sigma=0 must solve
    exactly like a hand-built CostModel carrying the measured scalars — the
    measured path adds no computation of its own, it only swaps scalars."""
    cm = analytical_cost_model("time")
    mt = _stats_table(cm, restore=[1.5e-5, 2.5e-5], commit=[1e-6, 1e-6])
    manual = CostModel(
        e_startup=mt.stats["restore"].mean,
        read=cm.read, write=cm.write,  # zero commit variance → scale 1.0
        name=cm.name + "+measured",
    )
    assert np.array_equal(cost_scalars(mt.cost_model()), cost_scalars(manual))
    g = lower_config(SMOKE_CONFIGS["qwen3-4b"], batch=2, seq=16, kind="time")
    qs = (q_min(g, manual), None)
    for backend in ("scan", "pallas"):
        a = solve(PartitionSpec(graph=g, cost=manual, q_grid=qs,
                                backend=backend))
        b = solve(PartitionSpec(graph=g, cost=mt, q_grid=qs,
                                backend=backend))
        _assert_sweeps_equal(a.sweep, b.sweep, backend)
    pa = solve(PartitionSpec(graph=g, cost=manual, q_max=qs[0],
                             backend="numpy")).partition()
    pb = solve(PartitionSpec(graph=g, cost=mt, q_max=qs[0],
                             backend="numpy")).partition()
    assert pa.e_total == pb.e_total and pa.bounds == pb.bounds


# ---------------------------------------------------------------------------
# Monotonicity: higher confidence ⇒ never fewer bursts, never lower Q_min
# ---------------------------------------------------------------------------

CONFIDENCES = (0.5, 0.7, 0.9, 0.99)


def _noisy_table(rng: random.Random, cm: CostModel) -> MeasuredCostTable:
    mu = max(float(cm.e_startup), 0.05)
    return _stats_table(
        cm,
        restore=[rng.gauss(mu, 0.3 * mu) for _ in range(40)],
        commit=[abs(rng.gauss(0.05, 0.02)) for _ in range(40)],
    )


def check_confidence_monotonicity(rng: random.Random) -> None:
    g, cm = random_task_graph(rng, min_tasks=2), random_cost_model(rng)
    mt = _noisy_table(rng, cm)
    # Q_min is non-decreasing in confidence
    qmins = [
        solve(PartitionSpec(graph=g, cost=mt, confidence=c,
                            objective="minimax", backend="numpy")).q_min()
        for c in CONFIDENCES
    ]
    for lo, hi in zip(qmins, qmins[1:]):
        assert hi >= lo
    # at a fixed Q: burst count and E_total non-decreasing, feasibility
    # monotone (feasible at high confidence ⇒ feasible at lower)
    for q in random_q_grid(rng, qmins[0], qmins[-1] * 1.5):
        bursts, totals = [], []
        for c in CONFIDENCES:
            try:
                p = solve(PartitionSpec(graph=g, cost=mt, confidence=c,
                                        q_max=q, backend="numpy")).partition()
                bursts.append(p.n_bursts)
                totals.append(p.e_total)
            except Infeasible:
                bursts.append(math.inf)
                totals.append(math.inf)
        for lo, hi in zip(bursts, bursts[1:]):
            assert hi >= lo, (q, bursts)
        for lo, hi in zip(totals, totals[1:]):
            assert hi >= lo, (q, totals)


def test_confidence_monotonicity_seeded():
    for seed in range(12):
        check_confidence_monotonicity(random.Random(seed))


# ---------------------------------------------------------------------------
# Crash-schedule soak: confidence-c plans survive ≥ c of perturbed replays
# ---------------------------------------------------------------------------


def _soak_completion_rate(confidence, seed: int = 0, n_replays: int = 500,
                          mu: float = 0.2, sigma: float = 0.05) -> float:
    """Plan a chain at `confidence` under its own priced Q_min, then replay
    with the activation draw perturbed (one gaussian draw per replay — the
    device's actual E_s is a fixed property measured with noise). A replay
    completes when every planned cycle fits the budget it was admitted
    under."""
    rng = random.Random(seed)
    from repro.core import GraphBuilder

    b = GraphBuilder()
    prev = None
    for t in range(8):
        name = f"p{t}"
        b.packet(name, 64, keep=t == 7)
        b.task(f"t{t}", reads=(prev,) if prev else (), writes=(name,),
               cost=rng.uniform(0.05, 0.4))
        prev = name
    g = b.build()
    base = CostModel(e_startup=mu, read=LinearTransfer(0.0, 0.0),
                     write=LinearTransfer(0.0, 0.0), name="soak")
    mt = _stats_table(base,
                      restore=[rng.gauss(mu, sigma) for _ in range(400)])
    q = solve(PartitionSpec(graph=g, cost=mt, confidence=confidence,
                            objective="minimax", backend="numpy")).q_min()
    plan = solve(PartitionSpec(graph=g, cost=mt, confidence=confidence,
                               q_max=q, backend="numpy")).partition()
    # non-startup residual per cycle (task energy; transfers priced at 0)
    residuals = [b.e_read + b.e_write + b.e_task for b in plan.bursts]
    completions = 0
    for _ in range(n_replays):
        draw = rng.gauss(mt.stats["restore"].mean, mt.stats["restore"].std)
        if all(r + draw <= q for r in residuals):
            completions += 1
    return completions / n_replays


@pytest.mark.parametrize("confidence", [0.7, 0.9])
def test_confidence_soak_completion_rate(confidence):
    rate = _soak_completion_rate(confidence)
    # binomial noise at n=500 stays well inside 0.04
    assert rate >= confidence - 0.04, (confidence, rate)


def test_soak_higher_confidence_completes_more():
    low = _soak_completion_rate(0.55, seed=3)
    high = _soak_completion_rate(0.99, seed=3)
    assert high >= low
    assert high >= 0.95


# ---------------------------------------------------------------------------
# Plan-table drift probe (staleness vs a refreshed profile)
# ---------------------------------------------------------------------------


def _probe(table, cfg, cm, **kwargs):
    from repro.core.plan_table import probe_plan_table

    return probe_plan_table(table, cfg, cost=cm, **kwargs)


@pytest.fixture(scope="module")
def probe_case(smoke_plan_table):
    cfg, cm, qs, table = smoke_plan_table("qwen3-4b")
    return cfg, cm, table


def test_probe_accepts_clean_measured(probe_case):
    cfg, cm, table = probe_case
    mt = _clean_table_for(cm)
    n = _probe(table, cfg, cm, k=None, measured=mt)
    assert n == table.n_buckets * table.n_q


def test_probe_accepts_drift_within_tolerance(probe_case):
    cfg, cm, table = probe_case
    mt = _stats_table(cm, restore=[float(cm.e_startup) * 1.001] * 4)
    assert _probe(table, cfg, cm, k=None, measured=mt, drift_tol=0.05) > 0


def test_probe_rejects_drifted_measured(probe_case):
    from repro.core.plan_table import StaleTableError

    cfg, cm, table = probe_case
    mt = _stats_table(cm, restore=[float(cm.e_startup) * 50.0] * 4)
    with pytest.raises(StaleTableError, match="drifted"):
        _probe(table, cfg, cm, k=None, measured=mt)


def test_probe_drift_tolerance_is_tunable(probe_case):
    from repro.core.plan_table import PlanTableError, StaleTableError

    cfg, cm, table = probe_case
    mt = _stats_table(cm, restore=[float(cm.e_startup) * 1.001] * 4)
    with pytest.raises(StaleTableError, match="drifted"):
        _probe(table, cfg, cm, k=None, measured=mt, drift_tol=1e-9)
    with pytest.raises(PlanTableError, match="drift_tol"):
        _probe(table, cfg, cm, measured=mt, drift_tol=-0.1)


def test_probe_rejects_kind_mismatch(probe_case):
    from repro.core.plan_table import StaleTableError

    cfg, cm, table = probe_case
    mt = MeasuredCostTable(cm, kind="memory")
    with pytest.raises(StaleTableError, match="kind"):
        _probe(table, cfg, cm, measured=mt)


def test_probe_exact_checks_still_run_with_measured(probe_case):
    """The measured drift check rides on top of — never replaces — the
    bitwise fingerprint check against the analytical model."""
    from repro.core.plan_table import StaleTableError

    cfg, cm, table = probe_case
    mt = _clean_table_for(cm)
    other = CostModel(e_startup=float(cm.e_startup) * 2, read=cm.read,
                      write=cm.write, name=cm.name)
    with pytest.raises(StaleTableError, match="fingerprint"):
        _probe(table, cfg, other, measured=mt)


# ---------------------------------------------------------------------------
# CLI round trips
# ---------------------------------------------------------------------------


def test_dse_calibrate_cli_round_trip(tmp_path, probe_case, capsys):
    from repro.launch.dse import main as dse_main

    cfg, cm, table = probe_case
    table_path = tmp_path / "plan.npz"
    table.save(str(table_path))
    ledger_path = tmp_path / "ledger.json"
    _ledger_matching(cm).dump_json(str(ledger_path), kind="time")
    rc = dse_main(["--arch", "qwen3-4b", "--calibrate", str(ledger_path),
                   "--out", str(table_path), "--probe", "2"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "accepted" in out
    calib_path = tmp_path / "plan.npz.calib.json"
    assert calib_path.exists()
    back = MeasuredCostTable.from_json(str(calib_path))
    assert back.cost_model().name == cm.name  # clean loop


def test_dse_calibrate_cli_rejects_drifted_ledger(tmp_path, probe_case,
                                                  capsys):
    from repro.launch.dse import main as dse_main

    cfg, cm, table = probe_case
    table_path = tmp_path / "plan.npz"
    table.save(str(table_path))
    drifted = EnergyLedger()
    for c in range(3):
        drifted.charge(0, c, restore=float(cm.e_startup) * 50.0, compute=0.1)
    ledger_path = tmp_path / "drifted.json"
    drifted.dump_json(str(ledger_path), kind="time")
    rc = dse_main(["--arch", "qwen3-4b", "--calibrate", str(ledger_path),
                   "--out", str(table_path), "--probe", "2"])
    assert rc == 1
    assert "STALE" in capsys.readouterr().err


@pytest.mark.slow
def test_traffic_replan_cli_round_trip(tmp_path, capsys):
    """One CLI round trip: traffic emits a calibration ledger, replans from
    it in-process (byte-identical on the clean loop), and the emitted
    ledger feeds back through `dse --calibrate` against the emitted table."""
    from repro.launch.dse import main as dse_main
    from repro.launch.traffic import main as traffic_main

    ledger_path = tmp_path / "ledger.json"
    table_path = tmp_path / "table.npz"
    rc = traffic_main([
        "--arch", "qwen3-4b", "--build", "--n", "2", "--shapes", "2x8x6",
        "--seed", "0", "--ledger-out", str(ledger_path),
        "--table-out", str(table_path),
        "--replan", "--expect-replan-identical",
    ])
    assert rc == 0
    out = capsys.readouterr().out
    assert "identical to the original" in out
    payload = json.loads(ledger_path.read_text())
    rows = payload["entries"]
    assert rows == sorted(rows, key=lambda r: (r["rid"], r["cycle"]))
    rc = dse_main(["--arch", "qwen3-4b", "--calibrate", str(ledger_path),
                   "--out", str(table_path), "--probe", "2"])
    assert rc == 0


# ---------------------------------------------------------------------------
# Hypothesis tier (runs when hypothesis is installed; see module docstring)
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    energies = st.floats(min_value=1e-9, max_value=1e3, allow_nan=False,
                         allow_infinity=False)

    class TestCalibrationHypothesis:
        @given(xs=st.lists(energies, min_size=1, max_size=80))
        @settings(max_examples=60, deadline=None)
        def test_welford_matches_statistics(self, xs):
            s = KernelStats()
            for x in xs:
                s.add(x)
            assert s.mean == pytest.approx(statistics.fmean(xs), rel=1e-9)
            assert s.variance == pytest.approx(
                statistics.pvariance(xs), rel=1e-6, abs=1e-15)

        @given(x=energies, n=st.integers(min_value=1, max_value=300))
        @settings(max_examples=60, deadline=None)
        def test_identical_samples_stay_bit_exact(self, x, n):
            s = KernelStats()
            for _ in range(n):
                s.add(x)
            assert s.mean == x and s.m2 == 0.0

        @given(
            rows=st.lists(
                st.tuples(st.integers(0, 5), st.integers(0, 5),
                          st.sampled_from(CATEGORIES), energies),
                min_size=1, max_size=60),
            seed=st.integers(0, 2 ** 16),
        )
        @settings(max_examples=40, deadline=None)
        def test_dump_interleaving_invariance(self, rows, seed, tmp_path):
            cm = analytical_cost_model("time")
            shuffled = list(rows)
            random.Random(seed).shuffle(shuffled)
            a, b = EnergyLedger(), EnergyLedger()
            for ledger, data in ((a, rows), (b, shuffled)):
                for rid, cycle, cat, e in data:
                    if cat == "replay":
                        ledger.overhead(rid, cycle, e)
                    else:
                        ledger.charge(rid, cycle, **{cat: e})
            pa, pb = tmp_path / "a.json", tmp_path / "b.json"
            a.dump_json(str(pa))
            b.dump_json(str(pb))
            fa = MeasuredCostTable.from_ledger_json(str(pa), base=cm)
            fb = MeasuredCostTable.from_ledger_json(str(pb), base=cm)
            assert fa.fingerprint() == fb.fingerprint()

        @given(
            restore=st.lists(energies, min_size=1, max_size=40),
            commit=st.lists(energies, min_size=0, max_size=40),
            c1=st.floats(min_value=0.5, max_value=0.999),
            c2=st.floats(min_value=0.5, max_value=0.999),
        )
        @settings(max_examples=60, deadline=None)
        def test_pricing_monotone_in_confidence(self, restore, commit,
                                                c1, c2):
            cm = analytical_cost_model("time")
            mt = _stats_table(cm, restore=restore, commit=commit)
            lo, hi = min(c1, c2), max(c1, c2)
            assert mt.e_startup(hi) >= mt.e_startup(lo)
            assert mt.transfer_scale(hi) >= mt.transfer_scale(lo)

        @given(restore=st.lists(energies, min_size=1, max_size=30))
        @settings(max_examples=40, deadline=None)
        def test_json_round_trip_property(self, restore, tmp_path):
            mt = _stats_table(analytical_cost_model("time"), restore=restore)
            path = tmp_path / "calib.json"
            mt.to_json(str(path))
            assert MeasuredCostTable.from_json(
                str(path)).fingerprint() == mt.fingerprint()

else:

    def test_calibration_fuzz_skipped_without_hypothesis():
        pytest.importorskip("hypothesis")


# ---------------------------------------------------------------------------
# Multi-host aggregation: MeasuredCostTable.merge (ISSUE 10 satellite)
# ---------------------------------------------------------------------------


def test_kernel_stats_merge_matches_sequential_ingest():
    """Chan's combine == sequential Welford over the concatenation: counts
    exact, moments to ~ulp (summation order is part of Welford rounding)."""
    rng = random.Random(21)
    for _ in range(30):
        xs = [rng.uniform(1e-6, 5.0) for _ in range(rng.randint(0, 40))]
        ys = [rng.uniform(1e-6, 5.0) for _ in range(rng.randint(0, 40))]
        a, b, ref = KernelStats(), KernelStats(), KernelStats()
        for x in xs:
            a.add(x)
        for y in ys:
            b.add(y)
        for v in xs + ys:
            ref.add(v)
        m = a.merge(b)
        assert m.count == ref.count
        if ref.count:
            assert m.mean == pytest.approx(ref.mean, rel=1e-12)
            assert m.m2 == pytest.approx(ref.m2, rel=1e-9, abs=1e-15)


def test_kernel_stats_merge_empty_side_is_bitwise():
    s = KernelStats()
    for x in (0.3, 1.7, 0.9):
        s.add(x)
    for merged in (s.merge(KernelStats()), KernelStats().merge(s)):
        assert (merged.count, merged.mean, merged.m2) == (s.count, s.mean, s.m2)


def test_kernel_stats_merge_identical_means_stay_bitwise():
    # delta == 0.0 → the shared mean survives bitwise and m2 adds exactly
    x = 2.0 ** -17 * 3.0
    a, b = KernelStats(), KernelStats()
    for _ in range(11):
        a.add(x)
    for _ in range(5):
        b.add(x)
    m = a.merge(b)
    assert m.mean == x and m.m2 == 0.0 and m.count == 16


def test_kernel_stats_merge_rejects_non_stats():
    with pytest.raises(CalibrationError):
        KernelStats().merge("nope")


def _rows_from(rng, n):
    cats = ("restore", "compute", "commit", "replay")
    return [
        {"category": rng.choice(cats), "energy": rng.uniform(1e-6, 2.0)}
        for _ in range(n)
    ]


def test_measured_table_merge_differential_vs_concatenated_ingest():
    """merge(per-device tables) == one table ingesting the concatenated rows
    (counts exact, moments ~ulp) — the multi-host aggregation contract."""
    rng = random.Random(33)
    base = analytical_cost_model("time")
    chunks = [_rows_from(rng, rng.randint(0, 25)) for _ in range(4)]
    parts = []
    for d, chunk in enumerate(chunks):
        t = MeasuredCostTable(base, "time", meta={"device": f"dev{d}"})
        t.ingest_rows(chunk)
        parts.append(t)
    merged = MeasuredCostTable.merge(*parts)
    ref = MeasuredCostTable(base, "time")
    ref.ingest_rows([r for chunk in chunks for r in chunk])
    assert merged.n_samples == ref.n_samples
    for cat in CATEGORIES:
        ms, rs = merged.stats[cat], ref.stats[cat]
        assert ms.count == rs.count
        if rs.count:
            assert ms.mean == pytest.approx(rs.mean, rel=1e-12)
            assert ms.m2 == pytest.approx(rs.m2, rel=1e-9, abs=1e-15)
    # per-device provenance rides in meta → to_payload
    prov = merged.meta["merged_from"]
    assert [p["meta"].get("device") for p in prov] == [
        "dev0", "dev1", "dev2", "dev3"
    ]
    assert [p["fingerprint"] for p in prov] == [t.fingerprint() for t in parts]
    assert sum(p["n_samples"] for p in prov) == merged.n_samples
    assert merged.to_payload()["meta"]["merged_from"] == prov


def test_measured_table_merge_single_table_is_bitwise():
    rng = random.Random(8)
    base = analytical_cost_model("time")
    t = MeasuredCostTable(base, "time")
    t.ingest_rows(_rows_from(rng, 17))
    m = MeasuredCostTable.merge(t)
    assert m.fingerprint() == t.fingerprint()  # stats bitwise-identical


def test_measured_table_merge_identical_fleet_keeps_fingerprint():
    # devices that measured identical draws merge to identical statistics
    base = analytical_cost_model("time")
    rows = [{"category": "restore", "energy": 3e-5}] * 9
    a = MeasuredCostTable(base, "time")
    a.ingest_rows(rows)
    b = MeasuredCostTable(base, "time")
    b.ingest_rows(rows + rows)
    fleet = MeasuredCostTable.merge(a, a)
    assert fleet.fingerprint() == b.fingerprint()


def test_measured_table_merge_typed_errors():
    base = analytical_cost_model("time")
    other = analytical_cost_model("memory")
    t1 = MeasuredCostTable(base, "time")
    with pytest.raises(CalibrationError, match="at least one"):
        MeasuredCostTable.merge()
    with pytest.raises(CalibrationError, match="MeasuredCostTable"):
        MeasuredCostTable.merge(t1, "nope")
    with pytest.raises(CalibrationError, match="different graph kinds"):
        MeasuredCostTable.merge(t1, MeasuredCostTable(base, "memory"))
    with pytest.raises(CalibrationError, match="different base models"):
        MeasuredCostTable.merge(t1, MeasuredCostTable(other, "time"))
