"""Continuous-traffic harness: arrival processes, energy-budget admission
control (defer / reject / replenish), continuation batching, crash-mid-queue
recovery, and the serving-counter reset hooks.

Fast tier: a FakeTable + SyntheticExecutor pair drives the *real*
ServePlanner, request_cycles, BurstRuntime, and TrafficHarness through tiny
numpy chain graphs — no jax, no XLA — so admission ordering, energy
accounting, and fault injection are pinned exactly. The slow tier runs the
same harness over real models via the shared ``serve_tables`` fixture and
pins zero retraces + planned/unplanned token equality under load.
"""

import dataclasses
import json

import numpy as np
import pytest


# -- shared synthetic fixtures (no jax) --------------------------------------

E_TOTAL = 0.25    # one token step (one graph traversal)
E_STARTUP = 0.1
GEN = 3           # default request: 0.1 + 3*0.25 = 0.85 energy units
REQ_E = E_STARTUP + GEN * E_TOTAL


@dataclasses.dataclass(frozen=True)
class FakePlan:
    batch: int
    seq_bucket: int
    e_total: float


class FakeTable:
    """Duck-typed PlanTable: exact-batch, smallest-covering-seq lookup."""

    def __init__(self, buckets, e_total=E_TOTAL, e_startup=E_STARTUP,
                 arch="fake", q_floor=None):
        self.arch = arch
        self.e_startup = e_startup
        self.e_total = e_total
        self.q_floor = q_floor
        self._buckets = sorted(buckets)

    def lookup(self, batch, seq, energy_budget=None):
        from repro.core.partition import Infeasible
        from repro.core.plan_table import UnknownBucketError

        if (self.q_floor is not None and energy_budget is not None
                and energy_budget < self.q_floor):
            raise Infeasible(f"budget {energy_budget} below Q grid")
        for (b, s) in self._buckets:
            if b == batch and s >= seq:
                return FakePlan(batch=b, seq_bucket=s, e_total=self.e_total)
        raise UnknownBucketError(f"no bucket covers {batch}x{seq}")


class SyntheticExecutor:
    """Executor contract implementation over tiny numpy chain graphs.

    Each request is ``gen`` +1 steps through the real BurstRuntime: the
    final sequence equals ``seed + gen``, so token correctness (including
    across crash replays) is a one-line assert.
    """

    def __init__(self, planner):
        self.planner = planner
        self.opened = []

    def open(self, batch, prompt_len, gen, *, seed=0, cycle_budget=None,
             prompts=None, plan=None, nvm=None, crash_hook=None):
        from repro.core import (
            BurstRuntime, CostModel, GraphBuilder, LinearTransfer, Partition,
        )
        from repro.core.burst import burst_detail
        from repro.launch.planner import request_cycles
        from repro.launch.traffic import Continuation, Request

        if plan is None:
            plan = self.planner.plan_for(batch, prompt_len + gen,
                                         cycle_budget)
        b = GraphBuilder()
        b.packet("prompts", 8, external=True)
        for k in range(gen - 1):
            b.packet(f"state{k}", 8)
        b.packet("sequence", 8, keep=True)

        def mk(k):
            def fn(inp):
                src = inp["prompts"] if k == 0 else inp[f"state{k - 1}"]
                name = "sequence" if k == gen - 1 else f"state{k}"
                return {name: np.asarray(src) + 1}
            return fn

        for k in range(gen):
            b.task(f"step{k}",
                   reads=("prompts",) if k == 0 else (f"state{k - 1}",),
                   writes=("sequence",) if k == gen - 1 else (f"state{k}",),
                   cost=plan.e_total, fn=mk(k))
        graph = b.build()
        cycles = request_cycles(gen, plan.e_total, cycle_budget,
                                e_startup=self.planner.e_startup)
        cost = CostModel(e_startup=self.planner.e_startup,
                         read=LinearTransfer(0.0, 0.0),
                         write=LinearTransfer(0.0, 0.0), name="synthetic")
        part = Partition(
            cycles, [burst_detail(graph, cost, i, j) for (i, j) in cycles],
            None,
        )
        rt = BurstRuntime(graph, part, nvm=nvm, cost=cost,
                          crash_hook=crash_hook)
        if rt.nvm.read_index() == 0:
            rt.seed_inputs(
                {"prompts": np.full((batch,), seed, dtype=np.int64)})
        self.opened.append((batch, prompt_len, gen, seed))
        return Continuation(
            request=Request(rid=len(self.opened) - 1, batch=batch,
                            prompt_len=prompt_len, gen=gen, seed=seed),
            plan=plan, cycles=list(cycles), runtime=rt,
            e_startup=self.planner.e_startup)


@pytest.fixture()
def synthetic():
    """(planner, executor) over a two-bucket fake table."""
    from repro.launch.planner import ServePlanner

    planner = ServePlanner(FakeTable([(1, 8), (2, 8)]))
    return planner, SyntheticExecutor(planner)


def _req(rid, t=0.0, gen=GEN, batch=1, seed=0):
    from repro.launch.traffic import Request

    return Request(rid=rid, batch=batch, prompt_len=2, gen=gen, time=t,
                   seed=seed)


def _events(report, kind):
    return [rid for (_, k, rid) in report.events if k.split(":")[0] == kind]


# -- _parse_buckets validation (satellite bugfix) ----------------------------


def test_parse_buckets_valid():
    from repro.launch.planner import _parse_buckets

    assert _parse_buckets("2x24,4x48") == [(2, 24), (4, 48)]
    assert _parse_buckets(" 2X24 ") == [(2, 24)]  # case/space insensitive


@pytest.mark.parametrize("bad,offender", [
    ("2x24,48", "48"),        # missing the x — previously an opaque unpack
    ("2x", "2x"),             # missing seq        ValueError deep in main()
    ("x24", "x24"),           # missing batch
    ("2x24x3", "2x24x3"),     # too many fields
    ("0x24", "0x24"),         # non-positive
    ("2xfoo", "2xfoo"),       # non-integer
])
def test_parse_buckets_malformed(bad, offender):
    from repro.launch.planner import _parse_buckets

    with pytest.raises(ValueError, match="BATCHxSEQ") as ei:
        _parse_buckets(bad)
    assert repr(offender) in str(ei.value)


def test_parse_shapes_validation():
    from repro.launch.traffic import _parse_shapes

    assert _parse_shapes("2x8x8,1x4x2") == [(2, 8, 8), (1, 4, 2)]
    with pytest.raises(ValueError, match="BATCHxPROMPTxGEN"):
        _parse_shapes("2x8")
    with pytest.raises(ValueError, match="'0x8x8'"):
        _parse_shapes("0x8x8")


# -- request_cycles edge cases (satellite) -----------------------------------


def test_request_cycles_gen_one():
    from repro.launch.planner import request_cycles

    # a single step is always one cycle, however small the budget
    assert request_cycles(1, 0.25, None, e_startup=0.1) == [(1, 1)]
    assert request_cycles(1, 0.25, 1e-6, e_startup=0.1) == [(1, 1)]
    assert request_cycles(0, 0.25, None) == []


def test_request_cycles_budget_below_single_step():
    from repro.launch.planner import request_cycles

    # budget < e_startup + step_energy: documented behavior is single-step
    # cycles (the step's *interior* segmentation fits Q by table
    # construction; grouping just can't merge steps)
    assert request_cycles(4, 0.25, 0.3, e_startup=0.1) == [
        (1, 1), (2, 2), (3, 3), (4, 4)]


def test_request_cycles_exact_fill_tolerance():
    from repro.launch.planner import request_cycles

    # 0.1 + 3*0.25 = 0.85 exactly fills the budget → groups of 3
    assert request_cycles(7, 0.25, 0.85, e_startup=0.1) == [
        (1, 3), (4, 6), (7, 7)]
    # within the shared solver tolerance (rel 1e-9): still not split
    assert request_cycles(7, 0.25, 0.85 - 8.5e-13, e_startup=0.1) == [
        (1, 3), (4, 6), (7, 7)]
    # clearly below: groups of 2
    assert request_cycles(7, 0.25, 0.85 - 1e-6, e_startup=0.1) == [
        (1, 2), (3, 4), (5, 6), (7, 7)]


# -- arrival processes -------------------------------------------------------


def test_deterministic_arrivals():
    from repro.launch.traffic import deterministic_arrivals

    reqs = deterministic_arrivals(3, 0.5, (2, 8, 4), start=1.0)
    assert [r.time for r in reqs] == [1.0, 1.5, 2.0]
    assert all(r.shape == (2, 8, 4) and r.max_seq == 12 for r in reqs)


def test_poisson_arrivals_deterministic_under_seed():
    from repro.launch.traffic import poisson_arrivals

    shapes = [(1, 4, 2), (2, 8, 4)]
    a = poisson_arrivals(16, 2.0, shapes, seed=7)
    b = poisson_arrivals(16, 2.0, shapes, seed=7)
    assert [(r.time, r.shape) for r in a] == [(r.time, r.shape) for r in b]
    c = poisson_arrivals(16, 2.0, shapes, seed=8)
    assert [r.time for r in a] != [r.time for r in c]
    times = [r.time for r in a]
    assert times == sorted(times) and times[0] > 0
    with pytest.raises(ValueError, match="rate"):
        poisson_arrivals(4, 0.0, shapes)


def test_trace_arrivals_and_load(tmp_path):
    from repro.launch.traffic import load_trace, trace_arrivals

    recs = [
        {"time": 2.0, "batch": 1, "prompt_len": 4, "gen": 2},
        (0.5, 2, 8, 4, 3),  # tuple form with seed
    ]
    reqs = trace_arrivals(recs)
    assert [r.time for r in reqs] == [0.5, 2.0]  # sorted by arrival
    assert reqs[0].seed == 3 and reqs[1].shape == (1, 4, 2)

    p = tmp_path / "trace.json"
    p.write_text(json.dumps([
        {"time": 0.0, "batch": 1, "prompt_len": 2, "gen": 3},
        {"time": 1.0, "batch": 2, "prompt_len": 2, "gen": 3},
    ]))
    loaded = load_trace(str(p))
    assert [r.batch for r in loaded] == [1, 2]


# -- HarvestModel ------------------------------------------------------------


def test_harvest_model_replenish_and_cap():
    from repro.launch.traffic import HarvestModel

    h = HarvestModel(capacity=1.0, rate=0.5, charge=0.2)
    h.replenish(1.0)
    assert h.charge == pytest.approx(0.7)
    h.replenish(10.0)  # caps at capacity
    assert h.charge == pytest.approx(1.0)
    assert h.harvested == pytest.approx(0.8)
    h.draw(0.85)
    assert h.charge == pytest.approx(0.15)
    assert h.spent == pytest.approx(0.85)


def test_harvest_model_fits_and_time_until():
    from repro.launch.traffic import HarvestModel

    h = HarvestModel(capacity=1.0, rate=0.5, charge=0.5)
    assert h.fits(0.5)          # exact fill, solver tolerance
    assert not h.fits(0.6)
    assert h.time_until(0.5) == 0.0
    assert h.time_until(0.8) == pytest.approx(0.6)
    assert h.time_until(2.0) == float("inf")  # over capacity: never
    assert h.can_ever_fit(0.9) and not h.can_ever_fit(1.5)

    static = HarvestModel(capacity=1.0, rate=0.0, charge=0.3)
    assert not static.can_ever_fit(0.5)  # no income: current charge is it
    assert static.can_ever_fit(0.3)


def test_harvest_model_validation():
    from repro.launch.traffic import HarvestModel

    with pytest.raises(ValueError, match="capacity"):
        HarvestModel(capacity=0.0)
    with pytest.raises(ValueError, match="rate"):
        HarvestModel(capacity=1.0, rate=-1.0)
    unbounded = HarvestModel(capacity=float("inf"))
    assert unbounded.fits(1e12)
    unbounded.replenish(5.0)  # no-op, no overflow


# -- admission control through the harness -----------------------------------


def test_admit_then_defer_then_replenish(synthetic):
    from repro.launch.traffic import HarvestModel, TrafficHarness

    planner, ex = synthetic
    harness = TrafficHarness(
        ex, harvest=HarvestModel(capacity=1.0, rate=0.5), keep_tokens=True)
    report = harness.run([_req(0), _req(1)])

    assert (report.arrived, report.admitted, report.deferred,
            report.rejected, report.completed) == (2, 2, 1, 0, 2)
    # r0 fits the initial charge; r1 waits for harvest income
    assert _events(report, "admit") == [0, 1]
    assert _events(report, "defer") == [1]
    assert _events(report, "complete") == [0, 1]
    # the planner carries the admission counters (satellite: observability)
    assert report.planner_delta["admitted"] == 2
    assert report.planner_delta["deferred"] == 1
    assert report.planner_delta["lookups"] == 2
    assert report.hit_rate == 1.0
    # energy ledger: both requests drawn, income credited
    assert report.energy_spent == pytest.approx(2 * REQ_E)
    # synthetic chain: sequence == seed + gen, replay-safe
    for rid in (0, 1):
        np.testing.assert_array_equal(report.tokens[rid],
                                      np.full((1,), GEN, dtype=np.int64))


def test_reject_over_capacity_and_no_replenishment(synthetic):
    from repro.launch.traffic import HarvestModel, TrafficHarness

    planner, ex = synthetic
    # capacity below one request's tabulated draw: can never fit
    r = TrafficHarness(ex, harvest=HarvestModel(capacity=0.5, rate=1.0)).run(
        [_req(0)])
    assert r.rejected == 1 and r.admitted == 0
    assert r.reject_reasons == {"over_capacity": 1}

    # fits capacity but rate=0 and charge too low: deferral would hang
    h = HarvestModel(capacity=2.0, rate=0.0, charge=0.5)
    r = TrafficHarness(ex, harvest=h).run([_req(0)])
    assert r.reject_reasons == {"no_replenishment": 1}
    assert r.planner_delta["rejected"] == 1


def test_reject_unknown_bucket_counts_miss(synthetic):
    from repro.launch.traffic import TrafficHarness

    planner, ex = synthetic
    report = TrafficHarness(ex, keep_tokens=True).run([
        _req(0), _req(1, batch=7), _req(2)])  # batch 7: no bucket
    assert report.completed == 2 and report.rejected == 1
    assert report.reject_reasons == {"UnknownBucketError": 1}
    assert report.planner_delta["lookups"] == 3
    assert report.planner_delta["misses"] == 1
    assert report.hit_rate == pytest.approx(2 / 3)


def test_deferral_queue_is_fifo(synthetic):
    from repro.launch.traffic import HarvestModel, TrafficHarness

    planner, ex = synthetic
    harness = TrafficHarness(
        ex, harvest=HarvestModel(capacity=0.9, rate=REQ_E))
    report = harness.run([_req(0), _req(1), _req(2)])
    assert report.admitted == 3 and report.deferred == 2
    assert _events(report, "admit") == [0, 1, 2]
    assert _events(report, "complete") == [0, 1, 2]


def test_cheap_request_may_overtake_deferred_head(synthetic):
    from repro.launch.traffic import HarvestModel, TrafficHarness

    planner, ex = synthetic
    # r0/r1 cost 0.85; r2 (gen=1) costs 0.35 and arrives later, when the
    # charge covers it but not the deferred head — documented overtake
    harness = TrafficHarness(
        ex, harvest=HarvestModel(capacity=0.9, rate=0.3))
    report = harness.run([_req(0), _req(1), _req(2, t=0.5, gen=1)])
    assert report.completed == 3
    assert _events(report, "admit") == [0, 2, 1]


def test_max_wait_rejects_stale_deferrals(synthetic):
    from repro.launch.traffic import HarvestModel, TrafficHarness

    planner, ex = synthetic
    harness = TrafficHarness(
        ex, harvest=HarvestModel(capacity=0.9, rate=0.01), max_wait=2.0)
    report = harness.run([_req(0), _req(1)])
    assert report.completed == 1 and report.rejected == 1
    assert report.reject_reasons == {"max_wait": 1}
    assert report.deferred == 1  # deferred first, then expired


def test_unlimited_harvest_admits_everything(synthetic):
    from repro.launch.traffic import TrafficHarness

    planner, ex = synthetic
    report = TrafficHarness(ex).run([_req(i) for i in range(5)])
    assert report.admitted == 5 and report.deferred == 0
    assert report.completed == 5
    assert report.final_charge == float("inf")


# -- continuation batching ---------------------------------------------------


def test_same_bucket_requests_drain_before_switching():
    from repro.launch.planner import ServePlanner
    from repro.launch.traffic import TrafficHarness

    planner = ServePlanner(FakeTable([(1, 8), (2, 8)]))
    ex = SyntheticExecutor(planner)
    # interleaved arrival of two buckets; 3 cycles per request via Q=0.4
    reqs = [_req(0, batch=1), _req(1, batch=2), _req(2, batch=1),
            _req(3, batch=2)]
    harness = TrafficHarness(ex, cycle_budget=0.4)
    report = harness.run(reqs)
    assert report.completed == 4
    assert report.cycles_run == 4 * 3
    # bucket 1x8 (r0, r2) fully drains, then one switch to 2x8 (r1, r3)
    assert report.executable_switches == 1
    # round-robin within a bucket: r0 and r2 finish adjacently
    assert _events(report, "complete") == [0, 2, 1, 3]


def test_round_robin_interleaves_cycles_within_bucket(synthetic):
    from repro.launch.traffic import TrafficHarness

    planner, ex = synthetic
    report = TrafficHarness(ex, cycle_budget=0.4).run(
        [_req(0), _req(1)])
    # 3 cycles each, interleaved: both complete at the end, in order
    assert report.cycles_run == 6
    assert _events(report, "complete") == [0, 1]
    assert report.commit_delta == {"commits": 6, "replays": 0}


# -- crash-mid-queue fault injection ----------------------------------------


def test_power_failure_mid_queue_replays_and_completes(synthetic):
    from repro.launch.traffic import HarvestModel, TrafficHarness

    planner, ex = synthetic

    class CrashOnce:
        def __init__(self):
            self.fired = False

        def __call__(self, b, phase):
            from repro.core import PowerFailure

            if not self.fired and b == 1 and phase == "executed":
                self.fired = True
                raise PowerFailure(f"injected at burst {b} ({phase})")

    hooks = {}

    def hook_for(request):
        if request.rid == 1:
            hooks[request.rid] = CrashOnce()
            return hooks[request.rid]
        return None

    harness = TrafficHarness(
        ex, cycle_budget=0.4, keep_tokens=True,
        harvest=HarvestModel(capacity=2.5, rate=1.0),
        crash_hook_factory=hook_for)
    report = harness.run([_req(0), _req(1)])

    assert hooks[1].fired
    assert report.power_failures == 1
    assert report.completed == 2
    # 6 cycles commit; the crashed one replays exactly once
    assert report.cycles_run == 6
    assert report.commit_delta == {"commits": 6, "replays": 1}
    # idempotent replay: tokens identical to the unfailed request
    np.testing.assert_array_equal(report.tokens[1], report.tokens[0])


def test_continuation_step_contract(synthetic):
    from repro.core import MemoryNVM, PowerFailure

    planner, ex = synthetic
    boom = {"armed": True}

    def hook(b, phase):
        if boom["armed"] and b == 1 and phase == "stored":
            boom["armed"] = False
            raise PowerFailure("injected")

    cont = ex.open(1, 2, GEN, cycle_budget=0.4, nvm=MemoryNVM(),
                   crash_hook=hook)
    assert cont.n_cycles == 3 and not cont.done
    assert cont.step() is False
    assert cont.cycles_done == 1
    with pytest.raises(PowerFailure):
        cont.step()
    assert cont.cycles_done == 1          # commit index survived the crash
    assert cont.step() is False           # replay of cycle 1
    assert cont.runtime.stats.replays == 1
    assert cont.step() is True
    assert cont.done and cont.step() is True  # idempotent once complete
    np.testing.assert_array_equal(cont.tokens(),
                                  np.full((1,), GEN, dtype=np.int64))
    # per-cycle cost: E_s + one step each under Q=0.4
    assert cont.cycle_cost(0) == pytest.approx(E_STARTUP + E_TOTAL)
    assert cont.total_cost == pytest.approx(3 * (E_STARTUP + E_TOTAL))


def test_crash_on_deferred_requests_first_cycle(synthetic):
    """Fault matrix × admission control: a request that was deferred by the
    harvest pool crashes on its very first cycle after finally being
    admitted. The replay books as overhead outside the admission
    reservation, the request still completes, and the ledger conserves."""
    from repro.launch.traffic import HarvestModel, TrafficHarness

    planner, ex = synthetic
    fired = {}

    class CrashFirstCycle:
        def __init__(self, rid):
            self.rid = rid

        def __call__(self, b, phase):
            from repro.core import PowerFailure

            if self.rid not in fired and b == 0 and phase == "executed":
                fired[self.rid] = True
                raise PowerFailure("injected on the deferred head's cycle 0")

    def hook_for(request):
        return CrashFirstCycle(request.rid) if request.rid == 1 else None

    # e_req = 3 * (E_STARTUP + E_TOTAL) = 1.05: rid 0 drains the pool, rid 1
    # must wait for harvest before admission
    harness = TrafficHarness(
        ex, cycle_budget=0.4, keep_tokens=True,
        harvest=HarvestModel(capacity=1.2, rate=0.5),
        crash_hook_factory=hook_for)
    report = harness.run([_req(0), _req(1, t=0.5)])

    assert fired == {1: True}
    assert report.deferred == 1 and report.admitted == 2
    assert report.completed == 2
    assert report.power_failures == 1
    assert report.commit_delta == {"commits": 6, "replays": 1}
    # the crashed attempt is booked as replay overhead on (rid=1, cycle=0),
    # at the full cycle draw, outside the reservation
    replays = [e for e in report.ledger.entries if e.category == "replay"]
    assert [(e.rid, e.cycle) for e in replays] == [(1, 0)]
    assert replays[0].energy == pytest.approx(E_STARTUP + E_TOTAL)
    assert report.ledger_conserved
    assert report.ledger.overhead_total() == pytest.approx(
        E_STARTUP + E_TOTAL)
    # idempotent replay: deferred-then-crashed output matches the clean one
    np.testing.assert_array_equal(report.tokens[1], report.tokens[0])


def test_crash_between_reservation_and_first_commit(synthetic):
    """Fault matrix × admission control: power failure after the admission
    reservation drew from the pool but before the first cycle ever
    committed ('loaded' phase — nothing durable yet). The reservation is
    not refunded, the replay books at the full cycle cost, and the request
    completes with conservation intact."""
    from repro.launch.traffic import HarvestModel, TrafficHarness

    planner, ex = synthetic
    state = {"fired": False}

    def hook(b, phase):
        from repro.core import PowerFailure

        if not state["fired"] and b == 0 and phase == "loaded":
            state["fired"] = True
            raise PowerFailure("injected before the first commit")

    harness = TrafficHarness(
        ex, cycle_budget=0.4, keep_tokens=True,
        harvest=HarvestModel(capacity=2.0, rate=1.0),
        crash_hook_factory=lambda r: hook)
    report = harness.run([_req(0)])

    assert state["fired"]
    assert report.power_failures == 1
    assert report.completed == 1
    # no cycle had committed, so the replay re-runs cycle 0 from scratch
    assert report.commit_delta == {"commits": 3, "replays": 1}
    replays = [(e.rid, e.cycle) for e in report.ledger.entries
               if e.category == "replay"]
    assert replays == [(0, 0)]
    assert report.ledger_conserved
    # charged total is the clean 3-cycle energy; the crashed attempt rides
    # on top as overhead
    assert report.ledger.charged_total() == pytest.approx(
        3 * (E_STARTUP + E_TOTAL))
    assert report.ledger.overhead_total() == pytest.approx(
        E_STARTUP + E_TOTAL)


# -- reset hooks + global counters (satellite) -------------------------------


def test_commit_stats_reset_and_diff(synthetic):
    from repro.core import COMMIT_STATS, reset_commit_stats
    from repro.launch.traffic import TrafficHarness

    planner, ex = synthetic
    reset_commit_stats()
    assert COMMIT_STATS == {"commits": 0, "replays": 0}
    TrafficHarness(ex).run([_req(0)])
    assert COMMIT_STATS["commits"] == 1  # gen=3, one unbounded cycle
    reset_commit_stats()
    assert COMMIT_STATS == {"commits": 0, "replays": 0}


def test_serve_planner_reset_stats_and_admission_validation():
    from repro.launch.planner import ServePlanner

    planner = ServePlanner(FakeTable([(1, 8)]))
    planner.plan_for(1, 5)
    planner.record_admission("admitted")
    assert planner.stats["lookups"] == 1 and planner.stats["admitted"] == 1
    assert planner.stats["by_bucket"] == {"1x8": 1}
    assert planner.hit_rate == 1.0
    planner.reset_stats()
    assert planner.stats["lookups"] == 0 and planner.stats["by_bucket"] == {}
    assert planner.hit_rate == 0.0
    with pytest.raises(ValueError, match="unknown admission outcome"):
        planner.record_admission("dropped")


def test_request_energy_matches_cycle_ledger(synthetic):
    from repro.launch.traffic import request_energy

    planner, ex = synthetic
    plan = planner.plan_for(1, 5)
    cycles, total = request_energy(plan, GEN, 0.4, planner.e_startup)
    assert cycles == [(1, 1), (2, 2), (3, 3)]
    assert total == pytest.approx(3 * (E_STARTUP + E_TOTAL))
    cycles, total = request_energy(plan, GEN, None, planner.e_startup)
    assert cycles == [(1, GEN)]
    assert total == pytest.approx(REQ_E)


def test_warmup_dedupes_shapes(synthetic):
    from repro.launch.traffic import TrafficHarness

    planner, ex = synthetic

    class WarmExec(SyntheticExecutor):
        def __init__(self, planner):
            super().__init__(planner)
            self.warmed = None

        def warmup(self, shapes, cycle_budget=None):
            self.warmed = list(shapes)

    wex = WarmExec(planner)
    harness = TrafficHarness(wex)
    n = harness.warmup([_req(0, seed=5), _req(1, t=1.0, seed=9),
                        _req(2, t=2.0, gen=1)])
    assert n == 2  # two distinct shapes
    # first-seen seed per shape, so the warmed params entry is reused
    assert sorted(wex.warmed) == [(1, 2, 1, 0), (1, 2, GEN, 5)]


def test_report_summary_and_percentiles(synthetic):
    from repro.launch.traffic import TrafficHarness

    planner, ex = synthetic
    report = TrafficHarness(ex).run([_req(i, t=0.25 * i) for i in range(4)])
    pct = report.latency_percentiles_ms()
    assert set(pct) == {"p50", "p95", "p99"}
    assert pct["p50"] <= pct["p95"] <= pct["p99"]
    assert report.requests_per_s > 0
    s = report.summary()
    assert "4/4 completed" in s and "retraces 0" in s
    assert report.trace_delta == {} or not any(report.trace_delta.values())


# -- slow tier: real models under the harness --------------------------------


@pytest.mark.slow
def test_traffic_harness_real_model_zero_retrace_and_token_equality(
        serve_tables):
    import repro.launch.serve as serve_mod
    from repro.launch.serve import PlannedExecutor
    from repro.launch.traffic import (
        HarvestModel, TrafficHarness, deterministic_arrivals, request_energy,
    )
    from tests.conftest import SERVE_BATCH, SERVE_GEN, SERVE_PROMPT

    arch = "qwen3-4b"
    ex = PlannedExecutor(arch, serve_tables[arch])
    shape = (SERVE_BATCH, SERVE_PROMPT, SERVE_GEN)
    plan = ex.planner.plan_for(SERVE_BATCH, SERVE_PROMPT + SERVE_GEN, None)
    _, e_req = request_energy(plan, SERVE_GEN, None, ex.planner.e_startup)

    # capacity holds ~1.5 requests, income ~0.9/unit-time: with three
    # arrivals the second defers, proving admission control against real
    # tabulated energies
    harness = TrafficHarness(
        ex, harvest=HarvestModel(capacity=1.5 * e_req, rate=0.9 * e_req),
        keep_tokens=True)
    reqs = deterministic_arrivals(3, 0.0, shape)
    harness.warmup(reqs)

    report = harness.run(reqs)
    assert report.completed == 3 and report.admitted == 3
    assert report.deferred >= 1
    # zero retraces after warmup — the continuous-traffic acceptance bar
    assert not any(report.trace_delta.values()), report.trace_delta
    assert report.hit_rate == 1.0
    assert report.commit_delta["commits"] == 3  # one unbounded cycle each

    # planned-under-harness tokens == unplanned serve() tokens
    unplanned = serve_mod.serve(arch, SERVE_BATCH, SERVE_PROMPT, SERVE_GEN)
    for rid in range(3):
        np.testing.assert_array_equal(report.tokens[rid],
                                      np.asarray(unplanned))


@pytest.mark.slow
def test_traffic_cli_smoke(capsys):
    from repro.launch.traffic import main

    rc = main([
        "--arch", "qwen3-4b", "--build", "--arrivals", "deterministic",
        "--n", "3", "--interval", "0.0", "--shapes", "2x8x6",
        "--capacity-requests", "1.5", "--rate-requests", "0.9",
        "--expect-admitted", "3", "--expect-deferred", "1",
        "--expect-zero-retrace",
    ])
    assert rc == 0
    out = capsys.readouterr().out
    assert "3/3 completed" in out
