"""Façade tier: PartitionSpec → Engine.solve pins.

Three layers, matching the acceptance criteria of the API redesign:

* **Differential per legacy entry point** (marked ``legacy`` — they call the
  deprecated shims on purpose): one ``Engine.solve(PartitionSpec)`` call
  reproduces, bit-identically, each of ``optimal_partition``,
  ``optimal_partition_multi``, ``sweep``, ``optimal_partition_k``,
  ``q_min``, ``sweep_jax``, ``sweep_jax_batched``, ``sweep_jax_sharded``,
  ``optimal_partition_jax``, and ``shard_plan_table`` on every smoke config.
* **Error paths**: ``Infeasible`` and ``UnsupportedObjective`` surface with
  the same type *and message* from every backend (numpy / scan / pallas /
  sharded) for the same spec; export mismatches raise the typed
  :class:`ExportMismatch` everywhere.
* **Registry**: backends self-register with capability flags, custom
  registries dispatch, and every legacy entry point emits exactly one
  :class:`DeprecationWarning`.

The static no-legacy-imports check at the bottom is the other half of the
deprecation story: no non-test module under ``src/`` imports a legacy entry
point directly (the CI gate enforces the dynamic version with
``-W error::DeprecationWarning``).
"""

import ast
import os
import random
import warnings

import numpy as np
import pytest

from conftest import PLAN_BUCKETS
from helpers_random import random_cost_model, random_task_graph

from repro.api import (
    Engine,
    EngineError,
    ExportMismatch,
    Infeasible,
    PartitionSpec,
    QGridSharding,
    Solution,
    SpecError,
    UnsupportedObjective,
    backend_names,
    default_engine,
    register_backend,
    solve,
)
from repro.configs import SMOKE_CONFIGS, resolve_config
from repro.core import lower_config, q_min, whole_app_partition
from repro.core.layer_profile import default_cost_model

ARCHS = sorted(SMOKE_CONFIGS)


@pytest.fixture(scope="session")
def arch_case():
    """arch → (graph, cost model, small Q grid spanning infeasible→whole-app),
    lowered once per session (every differential test reuses it)."""
    cache = {}

    def _case(arch):
        if arch not in cache:
            cfg = SMOKE_CONFIGS[arch]
            cm = default_cost_model("time")
            g = lower_config(cfg, batch=2, seq=16, kind="time")
            qmn = q_min(g, cm)
            hi = whole_app_partition(g, cm).e_total
            qs = [qmn * 0.5, qmn, float(np.sqrt(qmn * hi)), hi * 1.1, None]
            cache[arch] = (g, cm, qs)
        return cache[arch]

    return _case


def _assert_parts_equal(a, b, ctx=""):
    """Bit-level equality of two Optional[Partition] lists."""
    assert len(a) == len(b), ctx
    for i, (p, q) in enumerate(zip(a, b)):
        assert (p is None) == (q is None), (ctx, i)
        if p is None:
            continue
        assert p.bounds == q.bounds, (ctx, i)
        assert p.q_max == q.q_max, (ctx, i)
        assert p.e_total == q.e_total, (ctx, i)
        assert [d.total for d in p.bursts] == [d.total for d in q.bursts], (ctx, i)


def _assert_sweeps_equal(a, b, ctx=""):
    assert a.n_tasks == b.n_tasks, ctx
    for field in ("dp", "parent", "e_total", "feasible", "starts"):
        assert getattr(a, field).tobytes() == getattr(b, field).tobytes(), \
            (ctx, field)


# ---------------------------------------------------------------------------
# Differential: one façade call per legacy entry point, every smoke config
# ---------------------------------------------------------------------------


@pytest.mark.legacy
@pytest.mark.parametrize("arch", ARCHS)
def test_facade_matches_optimal_partition(arch, arch_case):
    from repro.core.partition import optimal_partition

    g, cm, qs = arch_case(arch)
    sol = solve(PartitionSpec(graph=g, cost=cm, q_max=qs[2], backend="numpy"))
    _assert_parts_equal([sol.partition()], [optimal_partition(g, cm, qs[2])])


@pytest.mark.legacy
@pytest.mark.parametrize("arch", ARCHS)
def test_facade_matches_optimal_partition_multi_and_sweep(arch, arch_case):
    from repro.core.partition import optimal_partition_multi, sweep

    g, cm, qs = arch_case(arch)
    sol = solve(PartitionSpec(graph=g, cost=cm, q_grid=tuple(qs),
                              backend="numpy"))
    _assert_parts_equal(sol.partitions(), optimal_partition_multi(g, cm, qs))
    _assert_parts_equal(sol.partitions(), sweep(g, cm, qs))


@pytest.mark.legacy
@pytest.mark.parametrize("arch", ARCHS)
@pytest.mark.parametrize("k_objective", ["sum", "max"])
def test_facade_matches_optimal_partition_k(arch, k_objective, arch_case):
    from repro.core.partition import optimal_partition_k

    g, cm, qs = arch_case(arch)
    k = min(3, g.n_tasks)
    for backend in ("numpy", "scan", "pallas"):
        sol = solve(PartitionSpec(graph=g, cost=cm, objective="exact_k",
                                  n_bursts=k, k_objective=k_objective,
                                  backend=backend))
        _assert_parts_equal(
            [sol.partition()],
            [optimal_partition_k(g, cm, k, objective=k_objective)],
            ctx=backend,
        )


@pytest.mark.parametrize("arch", ARCHS)
def test_facade_minimax_matches_q_min(arch, arch_case):
    """objective='minimax' == the (non-deprecated) numpy q_min on every
    backend — numpy, scan, and the Pallas kernel's minimax mode —
    bit-for-bit."""
    g, cm, qs = arch_case(arch)
    ref = q_min(g, cm)
    for backend in ("numpy", "scan", "pallas"):
        sol = solve(PartitionSpec(graph=g, cost=cm, objective="minimax",
                                  backend=backend))
        assert sol.q_min() == ref, backend


@pytest.mark.legacy
@pytest.mark.parametrize("arch", ARCHS)
def test_facade_matches_sweep_jax(arch, arch_case):
    from repro.core.partition_jax import sweep_jax

    g, cm, qs = arch_case(arch)
    sol = solve(PartitionSpec(graph=g, cost=cm, q_grid=tuple(qs)))
    _assert_sweeps_equal(sol.sweep, sweep_jax(g, cm, qs))


@pytest.mark.legacy
@pytest.mark.parametrize("arch", ARCHS)
def test_facade_matches_sweep_jax_batched(arch, arch_case):
    from repro.core.partition_jax import sweep_jax_batched

    g, cm, qs = arch_case(arch)
    g2 = lower_config(SMOKE_CONFIGS[arch], batch=2, seq=24, kind="time")
    sol = solve(PartitionSpec(graphs=(g, g2), cost=cm, q_grid=tuple(qs)))
    for a, b in zip(sol.sweeps, sweep_jax_batched([g, g2], cm, qs)):
        _assert_sweeps_equal(a, b, ctx=arch)


@pytest.mark.legacy
@pytest.mark.parametrize("arch", ARCHS)
def test_facade_matches_sweep_jax_sharded(arch, arch_case):
    from repro.core.partition_jax import sweep_jax_sharded

    g, cm, qs = arch_case(arch)
    sol = solve(PartitionSpec(graphs=(g,), cost=cm, q_grid=tuple(qs),
                              sharding=QGridSharding(n_shards=2)))
    ref = sweep_jax_sharded([g], cm, qs, n_shards=2)
    _assert_sweeps_equal(sol.sweeps[0], ref[0], ctx=arch)


@pytest.mark.legacy
@pytest.mark.parametrize("arch", ARCHS)
def test_facade_matches_optimal_partition_jax(arch, arch_case):
    from repro.core.partition_jax import optimal_partition_jax

    g, cm, qs = arch_case(arch)
    sol = solve(PartitionSpec(graph=g, cost=cm, q_max=qs[2]))
    _assert_parts_equal([sol.partition()],
                        [optimal_partition_jax(g, cm, qs[2])])


@pytest.mark.legacy
def test_facade_matches_pallas_sweep():
    """The CSR/Pallas backend through the façade == legacy sweep_jax
    (interpret mode; one config keeps the kernel tier fast)."""
    from repro.core.partition_jax import sweep_jax

    cfg = SMOKE_CONFIGS["qwen3-4b"]
    cm = default_cost_model("time")
    g = lower_config(cfg, batch=2, seq=16, kind="time")
    qs = (q_min(g, cm), None)
    sol = solve(PartitionSpec(graph=g, cost=cm, q_grid=qs, backend="pallas"))
    _assert_sweeps_equal(sol.sweep, sweep_jax(g, cm, list(qs),
                                              backend="pallas"))
    assert sol.backend == "pallas"


@pytest.mark.legacy
def test_build_plan_table_sharding_matches_shard_plan_table(smoke_plan_table):
    """build_plan_table(sharding=...) — the spec-shaped replacement — is
    byte-identical to the deprecated shard_plan_table."""
    from repro.core.plan_table import PlanTable, shard_plan_table

    cfg, cm, qs, single = smoke_plan_table("qwen3-4b")
    via_param = smoke_plan_table(
        "qwen3-4b", sharding=QGridSharding(4)
    )[3]
    legacy = shard_plan_table(cfg, PLAN_BUCKETS, qs, n_shards=4, cost=cm)
    for name in PlanTable._PAYLOAD:
        assert getattr(via_param, name).tobytes() == \
            getattr(legacy, name).tobytes(), name
    assert via_param.content_digest() == legacy.content_digest()
    assert via_param.content_digest() == single.content_digest()


@pytest.mark.legacy
def test_facade_mixed_auto_batch_matches_legacy(monkeypatch):
    """A mixed dense/CSR/TaskGraph batch under backend='auto' resolves and
    groups exactly like the legacy batched entry point."""
    from repro.core import partition_jax
    from repro.core.partition_jax import sweep_jax_batched

    rng = random.Random(11)
    g1, g2, g3 = (random_task_graph(rng, max_tasks=6) for _ in range(3))
    cm = random_cost_model(rng)
    monkeypatch.setattr(partition_jax, "_AUTO_DENSE_BYTES", 0)  # g3 → pallas
    qs = (None, 0.5)
    batch = (g1.to_arrays(), g2.to_csr_arrays(), g3)
    sol = solve(PartitionSpec(graphs=batch, cost=cm, q_grid=qs))
    assert sol.backend == "pallas+scan"
    for a, b in zip(sol.sweeps, sweep_jax_batched(list(batch), cm, list(qs))):
        _assert_sweeps_equal(a, b)


# ---------------------------------------------------------------------------
# Deprecation shims: every legacy entry point warns exactly once per call
# ---------------------------------------------------------------------------


@pytest.mark.legacy
def test_every_legacy_entry_point_warns():
    from repro.core import partition as p
    from repro.core import partition_jax as pj
    from repro.core import plan_table as pt

    rng = random.Random(0)
    g = random_task_graph(rng, max_tasks=5)
    cm = random_cost_model(rng)
    cfg = SMOKE_CONFIGS["qwen3-4b"]
    cmt = default_cost_model("time")
    calls = [
        ("optimal_partition", lambda: p.optimal_partition(g, cm)),
        ("optimal_partition_multi",
         lambda: p.optimal_partition_multi(g, cm, [None])),
        ("sweep", lambda: p.sweep(g, cm, [1e9])),
        ("optimal_partition_k", lambda: p.optimal_partition_k(g, cm, 1)),
        ("sweep_jax", lambda: pj.sweep_jax(g, cm, [None])),
        ("sweep_jax_batched", lambda: pj.sweep_jax_batched([g], cm, [None])),
        ("sweep_jax_sharded",
         lambda: pj.sweep_jax_sharded([g], cm, [None, 1e9], n_shards=2)),
        ("optimal_partition_jax", lambda: pj.optimal_partition_jax(g, cm)),
        ("shard_plan_table",
         lambda: pt.shard_plan_table(cfg, [(2, 16)], [None], n_shards=1,
                                     cost=cmt)),
    ]
    for name, fn in calls:
        with warnings.catch_warnings(record=True) as rec:
            warnings.simplefilter("always")
            fn()
        dep = [w for w in rec if issubclass(w.category, DeprecationWarning)
               and "legacy Julienning entry point" in str(w.message)]
        assert len(dep) == 1, (name, [str(w.message) for w in rec])
        assert name in str(dep[0].message), name


# ---------------------------------------------------------------------------
# Error paths: identical surfacing across numpy / scan / pallas / sharded
# ---------------------------------------------------------------------------

BACKEND_VARIANTS = [
    ("numpy", None),
    ("scan", None),
    ("pallas", None),
    ("scan", QGridSharding(n_shards=2)),
]
VARIANT_IDS = ["numpy", "scan", "pallas", "sharded"]


@pytest.fixture(scope="module")
def tiny_case():
    rng = random.Random(3)
    g = random_task_graph(rng, max_tasks=6, min_tasks=3)
    cm = random_cost_model(rng)
    return g, cm


@pytest.mark.parametrize("backend,sharding", BACKEND_VARIANTS, ids=VARIANT_IDS)
def test_infeasible_sum_surfaces_identically(backend, sharding, tiny_case):
    """An infeasible Q cell never fails at solve() time; it surfaces as the
    same Infeasible (same message) from Solution.partition() everywhere."""
    g, cm = tiny_case
    q_bad = q_min(g, cm) * 0.25
    spec = PartitionSpec(graph=g, cost=cm, q_grid=(q_bad, None),
                         backend=backend, sharding=sharding)
    sol = solve(spec)
    assert sol.e_total()[0] == np.inf
    with pytest.raises(Infeasible) as e:
        sol.partition(q_index=0)
    assert str(e.value) == f"Q_max={q_bad} admits no partition"
    sol.partition(q_index=1)  # the unbounded cell is always feasible


@pytest.mark.parametrize("backend", ["numpy", "scan", "pallas"])
def test_objective_matrix_every_builtin_backend(backend, tiny_case):
    """Every built-in backend implements all three objectives (the §4.4
    combines are Pallas kernel modes now): minimax reproduces the numpy
    q_min bit-for-bit and exact_k yields the requested burst count — no
    code path raises UnsupportedObjective for a built-in backend."""
    g, cm = tiny_case
    ref_qmin = q_min(g, cm)
    for objective, extra in (("minimax", {}),
                             ("exact_k", {"n_bursts": 2})):
        spec = PartitionSpec(graph=g, cost=cm, objective=objective,
                             backend=backend, **extra)
        sol = solve(spec)
        if objective == "minimax":
            assert sol.q_min() == ref_qmin
        else:
            assert sol.partition().n_bursts == 2


def test_unsupported_objective_surfaces_identically(tiny_case):
    """The UnsupportedObjective error path, pinned against a fake registered
    backend with a restricted objectives flag (the built-in backends all
    implement the full matrix now, so only capability flags can trip it)."""
    g, cm = tiny_case
    reg = {}

    @register_backend("sumonly", objectives=("sum",), supports_dense=True,
                      registry=reg)
    class SumOnly:
        name = "sumonly"

        def solve(self, req):
            raise AssertionError("capability check must reject pre-dispatch")

    eng = Engine(reg)
    for objective, extra in (("minimax", {}), ("exact_k", {"n_bursts": 2})):
        spec = PartitionSpec(graph=g, cost=cm, objective=objective,
                             backend="sumonly", **extra)
        with pytest.raises(UnsupportedObjective) as e:
            eng.solve(spec)
        msg = str(e.value)
        assert "'sumonly'" in msg and objective in msg
        # the message names who *does* implement it — nobody, here
        assert "implementing it: []" in msg
    # auto resolution over a registry with no capable backend is the same
    # typed error from the registry resolver
    with pytest.raises(UnsupportedObjective):
        eng.solve(PartitionSpec(graph=g, cost=cm, objective="minimax",
                                backend="auto"))


def test_named_backend_dispatch_errors_distinguish_registration(tiny_case):
    """resolve_jit_backend: an unknown name says 'unknown'; a registered but
    non-jit-dispatchable name (numpy) says so and lists both name sets
    instead of the old misleading 'unknown backend' message."""
    from repro.core.engine import resolve_jit_backend

    g, _ = tiny_case
    with pytest.raises(SpecError) as e:
        resolve_jit_backend(g, "numpy")
    msg = str(e.value)
    assert "registered but not jit-dispatchable" in msg
    for name in ("numpy", "scan", "pallas"):
        assert name in msg
    with pytest.raises(SpecError) as e2:
        resolve_jit_backend(g, "mosaic")
    msg2 = str(e2.value)
    assert "unknown backend 'mosaic'" in msg2 and "numpy" in msg2


def test_sharding_requires_a_q_grid_objective(tiny_case):
    """Only objective='sum' has a Q grid to shard: a sharded minimax/exact_k
    spec is rejected at construction, uniformly — no backend gets to
    silently ignore it."""
    g, cm = tiny_case
    for objective, extra in (("minimax", {}), ("exact_k", {"n_bursts": 2})):
        with pytest.raises(SpecError):
            PartitionSpec(graph=g, cost=cm, objective=objective,
                          sharding=QGridSharding(2), **extra)


@pytest.mark.parametrize("backend", ["numpy", "scan"])
def test_infeasible_exact_k_surfaces_identically(backend, tiny_case):
    g, cm = tiny_case
    q_bad = q_min(g, cm) * 0.25  # below Q_min: no 1..n-burst partition fits
    with pytest.raises(Infeasible) as e:
        solve(PartitionSpec(graph=g, cost=cm, objective="exact_k", n_bursts=2,
                            q_max=q_bad, backend=backend))
    assert str(e.value) == f"no 2-burst partition within Q_max={q_bad}"


def test_export_mismatch_is_typed_everywhere(tiny_case):
    g, cm = tiny_case
    cases = [
        (g.to_csr_arrays(), "scan"),    # CSR into the dense backend
        (g.to_arrays(), "pallas"),      # dense into the CSR backend
        (g.to_arrays(), "numpy"),       # any export into the reference DP
        (g.to_csr_arrays(), "numpy"),
    ]
    for export, backend in cases:
        with pytest.raises(ExportMismatch) as e:
            solve(PartitionSpec(graph=export, cost=cm, q_max=None,
                                backend=backend))
        assert isinstance(e.value, TypeError), (backend, type(export))
    with pytest.raises(ExportMismatch):
        solve(PartitionSpec(graph=object(), cost=cm, q_max=None))
    # layout gaps beat objective gaps: in a registry where the only
    # minimax-capable backend is dense-only, a CSR export is an export
    # problem, not an objective problem (the global registry can't hit this
    # branch anymore — pallas covers CSR for every objective)
    reg = {}

    @register_backend("denseonly", objectives=("sum", "minimax"),
                      supports_dense=True, registry=reg)
    class DenseOnly:
        name = "denseonly"

        def solve(self, req):
            raise AssertionError("layout check must reject pre-dispatch")

    with pytest.raises(ExportMismatch):
        Engine(reg).solve(PartitionSpec(graph=g.to_csr_arrays(), cost=cm,
                                        objective="minimax"))
    # exact_k prices bursts on the graph — exports are rejected up front
    # (before any solve), backend-independently
    from repro.core import partition_jax as pj

    solves = dict(pj.SOLVE_COUNT)
    with pytest.raises(ExportMismatch):
        solve(PartitionSpec(graph=g.to_arrays(), cost=cm,
                            objective="exact_k", n_bursts=2, backend="scan"))
    assert dict(pj.SOLVE_COUNT) == solves  # doomed spec never hit the engine


def test_numpy_backend_rejects_sharding(tiny_case):
    g, cm = tiny_case
    with pytest.raises(SpecError):
        solve(PartitionSpec(graph=g, cost=cm, q_grid=(None,),
                            backend="numpy", sharding=QGridSharding(2)))


# ---------------------------------------------------------------------------
# Spec validation + Solution accessors
# ---------------------------------------------------------------------------


def test_spec_validation(tiny_case):
    g, cm = tiny_case
    with pytest.raises(SpecError):
        PartitionSpec()                                  # no input source
    with pytest.raises(SpecError):
        PartitionSpec(graph=g, graphs=(g,))              # two sources
    with pytest.raises(SpecError):
        PartitionSpec(graph=g, q_grid=(None,), q_max=1.0)
    with pytest.raises(SpecError):
        PartitionSpec(graph=g, q_grid=())
    with pytest.raises(SpecError):
        PartitionSpec(graph=g, objective="minimax", q_max=1.0)
    with pytest.raises(SpecError):
        PartitionSpec(graph=g, objective="exact_k")      # n_bursts missing
    with pytest.raises(SpecError):
        PartitionSpec(graph=g, n_bursts=2)               # without exact_k
    with pytest.raises(SpecError):
        PartitionSpec(graph=g, objective="bottleneck")
    with pytest.raises(SpecError):
        PartitionSpec(graph=g, objective="exact_k", n_bursts=2,
                      k_objective="min")
    with pytest.raises(SpecError):
        QGridSharding(0)
    with pytest.raises(SpecError):
        solve(PartitionSpec(graph=g, q_max=None))        # cost required
    with pytest.raises(SpecError):
        solve(PartitionSpec(graph=g, cost=cm, backend="mosaic"))
    with pytest.raises(SpecError):
        solve(PartitionSpec(graph=g, cost=cm), q_max=1.0)  # spec + kwargs
    with pytest.raises(SpecError):
        default_engine().solve("not a spec")


def test_spec_is_immutable_and_normalized(tiny_case):
    g, cm = tiny_case
    spec = PartitionSpec(graph=g, cost=cm, q_grid=[1.0, None])
    assert spec.q_grid == (1.0, None)
    assert spec.q_values == (1.0, None)
    with pytest.raises(Exception):
        spec.backend = "scan"
    assert PartitionSpec(graph=g, cost=cm).q_values == (None,)
    assert PartitionSpec(graph=g, cost=cm,
                         objective="minimax").q_values == ()


def test_config_lowered_spec(arch_case):
    """config= specs lower exactly like the plan-table builders: same graphs,
    default cost per kind, smoke registry honored."""
    g_ref, cm, qs = arch_case("qwen3-4b")
    sol = solve(PartitionSpec(config="qwen3-4b", shapes=((2, 16),),
                              smoke=True, q_grid=tuple(qs)))
    direct = solve(PartitionSpec(graph=g_ref, cost=cm, q_grid=tuple(qs)))
    _assert_sweeps_equal(sol.sweeps[0], direct.sweep)
    assert sol.cost.name == cm.name
    assert resolve_config("qwen3-4b", smoke=True) is SMOKE_CONFIGS["qwen3-4b"]


def test_solution_accessor_guards(tiny_case):
    g, cm = tiny_case
    sum_sol = solve(PartitionSpec(graph=g, cost=cm, q_max=None,
                                  backend="numpy"))
    with pytest.raises(EngineError):
        sum_sol.q_min()
    with pytest.raises(EngineError):
        _ = sum_sol.sweep          # numpy backend has no JaxSweep payload
    mm_sol = solve(PartitionSpec(graph=g, cost=cm, objective="minimax",
                                 backend="scan"))
    with pytest.raises(EngineError):
        mm_sol.partitions()
    multi = solve(PartitionSpec(graphs=(g, g), cost=cm, q_max=None))
    with pytest.raises(EngineError):
        _ = multi.sweep            # 2 graphs: index .sweeps instead
    assert multi.n_graphs == 2 and "2 graph" in multi.summary()


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


def test_registry_flags_and_names():
    from repro.core.engine import backend_info

    assert {"numpy", "scan", "pallas"} <= set(backend_names())
    assert backend_info("scan").supports_sharding
    assert not backend_info("scan").supports_csr
    assert backend_info("pallas").supports_csr
    assert backend_info("pallas").objectives == \
        frozenset({"sum", "minimax", "exact_k"})
    assert not backend_info("numpy").auto_eligible
    assert backend_info("numpy").objectives == \
        frozenset({"sum", "minimax", "exact_k"})


def test_custom_backend_registration(tiny_case):
    """Downstream code can register a backend with capability flags and
    address it by name; capability checks guard its inputs."""
    from repro.core.engine import _REGISTRY

    g, cm = tiny_case
    registry = dict(_REGISTRY)
    seen = {}

    @register_backend("recorder", objectives=("sum",), supports_dense=True,
                      auto_eligible=False, registry=registry)
    class Recorder:
        def solve(self, req):
            seen["req"] = req
            return {"parts": tuple((None,) * len(req.q_values)
                                   for _ in req.graphs)}

    assert "recorder" not in backend_names()          # global untouched
    eng = Engine(registry=registry)
    sol = eng.solve(PartitionSpec(graph=g, cost=cm, q_grid=(1.0, None),
                                  backend="recorder"))
    assert sol.backend == "recorder"
    assert seen["req"].q_values == (1.0, None)
    with pytest.raises(Infeasible):
        sol.partition()                               # recorder said None
    with pytest.raises(ExportMismatch):
        eng.solve(PartitionSpec(graph=g.to_csr_arrays(), cost=cm,
                                backend="recorder"))
    with pytest.raises(SpecError):
        register_backend("bad", objectives=("frobnicate",))


def test_register_backend_rejects_unknown_objective_before_decorating():
    with pytest.raises(SpecError):
        register_backend("x", objectives=("sum", "nope"), registry={})


# ---------------------------------------------------------------------------
# Static guard: no non-test module in src/ imports a legacy entry point
# ---------------------------------------------------------------------------

LEGACY_NAMES = {
    "optimal_partition", "optimal_partition_multi", "optimal_partition_k",
    "sweep", "sweep_jax", "sweep_jax_batched", "sweep_jax_sharded",
    "optimal_partition_jax", "shard_plan_table",
}
# attribute accesses are checked too, for the names that are unambiguous
# ("sweep" is excluded: Solution.sweep / Solution.sweeps are façade API)
LEGACY_ATTRS = LEGACY_NAMES - {"sweep"}
# the exact modules that define / re-export the shims (everything else in
# src/, *including* other packages' __init__.py files, is checked)
DEFINING = {
    os.path.join("repro", "core", "partition.py"),
    os.path.join("repro", "core", "partition_jax.py"),
    os.path.join("repro", "core", "plan_table.py"),
    os.path.join("repro", "core", "__init__.py"),
}


def test_no_src_module_imports_legacy_entry_points():
    """No non-test module under src/ reaches a legacy entry point — neither
    `from x import optimal_partition` nor `mod.optimal_partition(...)`. The
    CI deprecation gate is the dynamic half of this check; the AST walk
    also catches module-level and slow-path-only call sites no fast test
    executes."""
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    offenders = []
    for dirpath, _, files in os.walk(src):
        for fname in files:
            if not fname.endswith(".py"):
                continue
            path = os.path.join(dirpath, fname)
            if os.path.relpath(path, src) in DEFINING:
                continue
            tree = ast.parse(open(path).read(), filename=path)
            for node in ast.walk(tree):
                bad = set()
                if isinstance(node, ast.ImportFrom):
                    bad = {a.name for a in node.names} & LEGACY_NAMES
                elif isinstance(node, ast.Attribute):
                    bad = {node.attr} & LEGACY_ATTRS
                if bad:
                    offenders.append(
                        (os.path.relpath(path, src), node.lineno, sorted(bad))
                    )
    assert not offenders, offenders


def test_legacy_guard_walk_covers_the_placement_subsystem():
    """The AST walk above discovers files by os.walk — pin that the swarm
    placement modules (and the data/ loader package) are actually under it,
    so a future src-layout move can't silently exempt them."""
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    walked = set()
    for dirpath, _, files in os.walk(src):
        for fname in files:
            if fname.endswith(".py"):
                walked.add(os.path.relpath(os.path.join(dirpath, fname), src))
    for mod in (
        os.path.join("repro", "core", "placement.py"),
        os.path.join("repro", "core", "placement_jax.py"),
        os.path.join("repro", "data", "ns_optimizer.py"),
        os.path.join("repro", "launch", "swarm.py"),
    ):
        assert mod in walked, mod


def test_placement_api_exported_through_facade():
    import repro.api as api

    for name in (
        "LinkModel", "NodeSpec", "PlacementError", "PlacementPlan",
        "PlacementSpec", "PlacementSweep", "PlacementTable",
    ):
        assert name in api.__all__, name
        assert getattr(api, name) is not None
    # and through repro.core, still without importing jax
    import repro.core as core

    assert core.PlacementSpec is api.PlacementSpec
    assert core.solve_placement_numpy is not None
