"""End-to-end energy-bounded serving from a precomputed plan table.

Builds a Q-grid plan table offline (one batched partitioner call over all
shape buckets), then serves requests from it: each request is an O(1) table
lookup, the token steps are grouped into energy cycles, and the request
executes through BurstRuntime — so a mid-request power failure resumes from
the last committed cycle instead of restarting. The injected-crash request
below produces the exact same tokens as the clean one.

Run:  PYTHONPATH=src python examples/serve_planned.py
"""

import numpy as np

from repro.core import MemoryNVM, PowerFailure
from repro.launch.planner import build_table_for_arch
from repro.launch.serve import serve

ARCH, BATCH, PROMPT, GEN = "qwen3-4b", 2, 8, 8

table = build_table_for_arch(ARCH, [(BATCH, PROMPT + GEN)], n_q=8)
print(f"[example] {table.summary()}")

plan = table.lookup(BATCH, PROMPT + GEN, None)
budget = plan.e_total * 2.5 + table.e_startup  # ~2 token steps per cycle

clean = serve(ARCH, BATCH, PROMPT, GEN, plan_table=table, energy_budget=budget)


class CrashOnce:
    fired = 0

    def __call__(self, b, phase):
        if b == 1 and phase == "executed" and not self.fired:
            self.fired = 1
            raise PowerFailure("power failure mid-request")


crashed = serve(ARCH, BATCH, PROMPT, GEN, plan_table=table,
                energy_budget=budget, nvm=MemoryNVM(), crash_hook=CrashOnce())
np.testing.assert_array_equal(np.asarray(clean), np.asarray(crashed))
print("[example] crash-interrupted request resumed from the committed "
      "cycle and produced identical tokens")
