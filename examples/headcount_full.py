"""Paper reproduction at full scale: the *unreduced* head-count graphs.

Solves both 5458-task applications (thermal FLIR Lepton and visual OV7670,
Table 2) over a Q_max grid through the CSR/Pallas sweep backend — the dense
``(N, R)`` export would be ~1 GB because the sort task reads all 5452 score
packets, so only the compressed slot layout makes the full graph a
single-kernel solve — and prints the paper-style energy-storage-reduction
table (Figs. 6–8): bursts, total energy, overhead, and storage reduction
versus the Whole-Application baseline.

Run:  PYTHONPATH=src python examples/headcount_full.py
"""

import time

import numpy as np

from repro.api import PartitionSpec, solve
from repro.core import dense_export_nbytes, q_min, whole_app_partition
from repro.core.apps.headcount import THERMAL, VISUAL, build_graph, paper_cost_model

cm = paper_cost_model()

for spec in (THERMAL, VISUAL):
    g = build_graph(spec)
    csr = g.to_csr_arrays()
    r = max(len(t.reads) for t in g.tasks)
    w = max(len(t.writes) for t in g.tasks)
    dense = dense_export_nbytes(g.n_tasks, r, w)
    print(f"=== {spec.name}: {g.n_tasks} tasks, "
          f"{csr.nnz_reads} read slots (max degree {r}) ===")
    e_app = g.total_task_cost()
    q_whole = whole_app_partition(g, cm).max_burst
    qmn = q_min(g, cm)
    qs = [qmn] + list(np.geomspace(qmn * 1.01, e_app * 1.05, 7)) + [None]

    t0 = time.time()
    sol = solve(PartitionSpec(graph=g, cost=cm, q_grid=tuple(qs)))
    res = sol.sweep  # auto -> CSR/Pallas sweep kernel
    dt = time.time() - t0
    print(f"export: dense would be {dense / 1e6:.0f} MB, CSR is "
          f"{csr.nbytes / 1e3:.0f} kB ({dense / csr.nbytes:.0f}x smaller) "
          f"-> backend={sol.backend}")
    print(f"solved {len(qs)} Q points in {dt:.1f}s (one fused kernel)")
    print(f"{'Q_max [mJ]':>12} {'bursts':>7} {'E_total [J]':>12} "
          f"{'overhead %':>11} {'storage reduction %':>20}")
    for qi, q in enumerate(qs):
        if not res.feasible[qi]:
            print(f"{(q or 0) * 1e3:12.2f} {'—':>7}  (infeasible)")
            continue
        b = res.bounds(qi)
        e_tot = res.e_total[qi]
        qv = q_whole if q is None else q
        print(f"{'unbounded' if q is None else f'{q * 1e3:.2f}':>12} "
              f"{len(b):7d} {e_tot:12.6f} "
              f"{100 * (e_tot - e_app) / e_tot:11.3f} "
              f"{100 * (1 - qv / q_whole):20.2f}")
    print(f"paper ({spec.name}): Q_min storage reduction "
          f"{100 * (1 - qmn / q_whole):.1f}% (paper reports >94% for thermal; "
          f"18 bursts @ 132 mJ, 0.12% overhead)\n")
