"""Swarm placement in 60 seconds: one model, N batteryless nodes.

Builds an NS-Optimizer-style CNN relay chain, then asks one batched
``Engine.solve`` call for the best way to split it across three harvesting
nodes at every link bandwidth from 900 to 3300 mbps — per-node burst
budgets, NVM caps, and hop transfer pricing all solved in one grid.

Run:  PYTHONPATH=src python examples/swarm_sweep.py
"""

from repro.api import (
    LinkModel, NodeSpec, PartitionSpec, PlacementSpec, solve,
)
from repro.core import GraphBuilder
from repro.core.layer_profile import default_cost_model

# 1. The application: a 6-layer CNN as a sequential chain (what
#    repro.data.ns_optimizer loads from prof.csv/dep.csv; built inline here).
#    Costs are layer seconds, packets are activation bytes.
b = GraphBuilder()
layers = [
    ("conv1", 0.020, 600_000),
    ("conv2", 0.015, 300_000),
    ("conv3", 0.012, 250_000),
    ("pool", 0.004, 120_000),
    ("fc1", 0.009, 40_000),
    ("fc2", 0.006, 4_000),
]
prev = None
for name, secs, nbytes in layers:
    b.packet(f"out:{name}", nbytes, keep=(name == "fc2"))
    b.task(name, reads=(f"out:{prev}",) if prev else (),
           writes=(f"out:{name}",), cost=secs)
    prev = name
graph = b.build()
cm = default_cost_model("time")

# 2. The swarm: three nodes, each with a burst budget and a 900 KB NVM —
#    too small to hold the whole activation footprint, so the chain *must*
#    split — swept across nine link bandwidths in ONE batched solve.
spec = PlacementSpec(
    nodes=tuple(
        NodeSpec(q_max=0.030, memory_bytes=900_000, name=f"cam{k}")
        for k in range(3)
    ),
    links=tuple(LinkModel(bandwidth_mbps=bw)
                for bw in range(900, 3400, 300)),
)
sol = solve(PartitionSpec(graph=graph, cost=cm, placement=spec))
sweep = sol.placement_sweep()
print(f"solved {sweep.summary()} on backend {sol.backend}\n")

# 3. The bandwidth sweep: faster links make multi-node splits cheaper.
print("bandwidth   E_total     nodes  transfer")
for li, link in enumerate(spec.links):
    plan = sweep.plan(link_index=li)
    print(f"{link.bandwidth_mbps:7g}   {plan.e_total:.6f}   "
          f"{plan.n_nodes_used}      {100 * plan.transfer_overhead:5.2f}%")

# 4. Zoom into the best cell: spans, per-node energy, hop accounting —
#    and the conservation proof (per-node ledgers sum to the plan total).
best = min((p for p in sweep.plans() if p is not None),
           key=lambda p: p.e_total)
print(f"\nbest: {best.summary()}")
for k, (lo, hi) in enumerate(best.spans):
    print(f"  {spec.nodes[k].name}: tasks {lo}..{hi}, "
          f"{len(best.node_bursts[k])} bursts, "
          f"E={best.node_energy[k]:.6f}, "
          f"NVM={best.node_memory_bytes[k]:,.0f} B, "
          f"spent={best.node_spent(k):.6f}")
for h, bnd in enumerate(best.hop_boundaries):
    print(f"  hop after task {bnd}: {best.hop_bytes[h]:,.0f} B, "
          f"tx={best.hop_tx[h]:.6f} rx={best.hop_rx[h]:.6f} "
          f"({best.hop_latency_s[h] * 1e3:.2f} ms)")
best.check_conservation()
print("per-node energy ledgers conserve ✓")
