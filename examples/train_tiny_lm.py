"""End-to-end driver: burst-checkpointed LM training with crash recovery.

Trains a small decoder-only LM on the synthetic pipeline for a few hundred
steps, checkpointing in bursts (paper Algorithm 1); then simulates a node
failure and resumes, verifying the loss trajectory continues exactly.

On CPU this uses the reduced config (a few M params, runs in ~2 minutes).
On real hardware pass ``--full --production-mesh`` via repro.launch.train to
drive the full configs — the code path is identical.

Run:  PYTHONPATH=src python examples/train_tiny_lm.py
"""

import tempfile

import numpy as np

from repro.launch.train import train

STEPS = 200

with tempfile.TemporaryDirectory() as ckpt:
    print("=== phase 1: train to step 100, then 'crash' ===")
    losses_1 = train("tinyllama-1.1b", steps=100, batch=8, seq=128,
                     burst_steps=50, ckpt_dir=ckpt, smoke=True, log_every=25)

    print("\n=== phase 2: resume from the committed burst, train to 200 ===")
    losses_2 = train("tinyllama-1.1b", steps=STEPS, batch=8, seq=128,
                     burst_steps=50, ckpt_dir=ckpt, smoke=True, log_every=25)

print(f"\nloss: start {losses_1[0]:.3f} → step 100 {losses_1[-1]:.3f} → "
      f"step {STEPS} {losses_2[-1]:.3f}")
assert losses_2[-1] < losses_1[0] - 1.0, "model should be learning"
print("resume continued the trajectory (same data cursor, same state).")
