"""Batched serving example: prefill + decode across the architecture zoo.

Exercises the serving path (sequence-sharded KV caches / recurrent state)
for one arch of each family — dense GQA, MoE, SSM, hybrid, enc-dec, VLM.

Run:  PYTHONPATH=src python examples/serve_batched.py
"""

from repro.launch.serve import serve

for arch in ("qwen3-4b", "granite-moe-1b-a400m", "xlstm-1.3b",
             "zamba2-7b", "whisper-large-v3", "llama-3.2-vision-11b"):
    serve(arch, batch=2, prompt_len=16, gen=8, smoke=True)
