"""Fleet-scale design-space exploration: the whole model zoo, one kernel.

Lowers every registered architecture to a Ladybirds task graph
(`lower_zoo`), pads them to a common shape, and solves the optimal burst
partition for all of them across a shared 256-point Q_max grid in a single
vmapped, jit-compiled dispatch (`sweep_jax_batched`) — the NS-Optimizer-style
"sweep every device config" workflow at hardware speed.

Two cost readings of the same graphs (DESIGN: time vs memory):

* time    — E_task = seconds at peak FLOPs, transfers over PCIe (offload);
  Q_max bounds per-segment seconds, E_total is end-to-end time.
* memory  — E_task = activation working bytes, E_s = 0; Q_max bounds
  per-segment HBM, Q_min is the smallest feasible activation budget (§4.4).

Run:  PYTHONPATH=src python examples/zoo_sweep.py
"""

import time

import numpy as np

from repro.api import PartitionSpec, solve
from repro.core import (
    lower_zoo,
    memory_cost_model,
    q_min,
    tpu_host_offload_model,
)

B, S, NQ = 8, 4096, 256

print(f"=== time reading: B={B} S={S}, PCIe offload transfers ===")
cm = tpu_host_offload_model()
zoo = lower_zoo(batch=B, seq=S)
names = sorted(zoo)
qmns = {n: q_min(zoo[n], cm) for n in names}
qs = list(np.geomspace(min(qmns.values()), max(qmns.values()) * 64, NQ))

spec = PartitionSpec(graphs=tuple(zoo[n] for n in names), cost=cm,
                     q_grid=tuple(qs))
solve(spec)  # compile once
t0 = time.time()
results = solve(spec).sweeps
dt = time.time() - t0
print(f"{len(names)} graphs x {NQ} Q points in one vmapped call: "
      f"{dt * 1e3:.1f} ms ({len(names) * NQ / dt:.0f} designs/s)\n")

hdr = f"{'arch':<24} {'tasks':>5} {'Q_min':>9} {'bursts@Qmin':>11} {'bursts@8x':>9} {'ovh@8x':>7}"
print(hdr)
print("-" * len(hdr))
for name, res in zip(names, results):
    g = zoo[name]
    feas = np.flatnonzero(res.feasible)
    qi_lo = int(feas[0])
    # closest grid point to 8x this graph's Q_min
    qi_8 = int(np.argmin(np.abs(np.array(qs) - 8 * qmns[name])))
    if not res.feasible[qi_8]:
        qi_8 = qi_lo
    e_app = g.total_task_cost()
    ovh = 100.0 * (res.e_total[qi_8] - e_app) / res.e_total[qi_8]
    print(f"{name:<24} {g.n_tasks:>5} {qmns[name] * 1e3:>7.2f}ms "
          f"{len(res.bounds(qi_lo)):>11} {len(res.bounds(qi_8)):>9} {ovh:>6.2f}%")

print(f"\n=== memory reading: B=1 S=128, Q_max bounds per-segment bytes ===")
cm_m = memory_cost_model()
zoo_m = lower_zoo(batch=1, seq=128, kind="memory")
names_m = sorted(zoo_m)
for name in names_m:
    g = zoo_m[name]
    qmn = q_min(g, cm_m)
    res = solve(PartitionSpec(graph=g, cost=cm_m, q_grid=(qmn, qmn * 4))).sweep
    print(f"{name:<24} min activation budget {qmn / 1e3:8.1f} kB  "
          f"segments: {len(res.bounds(0))} @Qmin, {len(res.bounds(1))} @4x")
