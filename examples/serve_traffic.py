"""Continuous-traffic serving over a precomputed plan table.

Builds a plan table offline, then sustains a Poisson-like request stream
through the traffic harness: every arrival is bucketed with one O(1) table
lookup, admission control reserves each request's tabulated energy against a
replenishing harvest pool (deferring what doesn't fit yet), and admitted
requests execute as interleaved energy cycles through BurstRuntime — with
every cycle hitting the same cached jitted executables (zero retraces after
warmup). A crash-prone request replays its failed cycle from the committed
NVM index and still returns the same tokens as its clean twin.

Run:  PYTHONPATH=src python examples/serve_traffic.py
"""

import numpy as np

from repro.core import PowerFailure
from repro.launch.planner import build_table_for_arch
from repro.launch.serve import PlannedExecutor
from repro.launch.traffic import (
    HarvestModel, TrafficHarness, poisson_arrivals, request_energy)

ARCH, BATCH, PROMPT, GEN = "qwen3-4b", 2, 8, 8

table = build_table_for_arch(ARCH, [(BATCH, PROMPT + GEN)], n_q=8)
print(f"[example] {table.summary()}")

executor = PlannedExecutor(ARCH, table)
plan = executor.planner.plan_for(BATCH, PROMPT + GEN, None)
_, e_req = request_energy(plan, GEN, None, executor.planner.e_startup)

# a pool that stores ~2 requests and harvests ~0.8 requests per unit time:
# bursts of arrivals overrun the pool and defer until income catches up
requests = poisson_arrivals(10, rate=3.0, shapes=[(BATCH, PROMPT, GEN)],
                            seed=0)


class CrashOnce:
    """Power failure during request 4's second cycle — replayed, not lost."""

    fired = 0

    def __call__(self, b, phase):
        if b == 1 and phase == "executed" and not self.fired:
            self.fired = 1
            raise PowerFailure("power failure mid-request")


harness = TrafficHarness(
    executor,
    harvest=HarvestModel(capacity=2 * e_req, rate=0.8 * e_req),
    cycle_budget=plan.e_total * 2.5 + table.e_startup,  # ~2 steps per cycle
    keep_tokens=True,
    crash_hook_factory=lambda r: CrashOnce() if r.rid == 4 else None,
)
harness.warmup(requests)
report = harness.run(requests)

print(f"[example] {report.summary()}")
assert report.completed == report.admitted
assert report.deferred >= 1, "pool sized to force at least one deferral"
assert not any(report.trace_delta.values()), "zero retraces after warmup"
assert report.power_failures == 1

# idempotent recovery: the crash-interrupted request matches a clean one
clean = min(r for r in report.tokens if r != 4)
np.testing.assert_array_equal(report.tokens[4], report.tokens[clean])
print("[example] crash-interrupted request replayed its cycle and produced "
      "identical tokens; deferred requests admitted as the pool refilled")
