"""Sharded design-space exploration with incremental table growth.

Runs the offline Julienning DSE three ways over the same bucket fleet and
shows they are interchangeable bit-for-bit:

1. single-host: one batched engine call over the whole bucket × Q grid;
2. sharded: the Q grid pmapped across an 8-device mesh (emulated below via
   XLA_FLAGS — on real hardware the same code spans a TPU pod slice);
3. incremental: start from half the fleet and `extend_plan_table` the rest
   in, without re-solving a single existing cell.

All three tables share one content digest, and the loaded table passes the
live-engine staleness probe. The XLA flag must be set before jax
initializes, which is why it is pinned at the very top.

Run:  PYTHONPATH=src python examples/dse_sharded.py
"""

import os

os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")

import jax  # noqa: E402

from repro.api import QGridSharding  # noqa: E402
from repro.configs import SMOKE_CONFIGS  # noqa: E402
from repro.core import (  # noqa: E402
    build_plan_table, extend_plan_table, probe_plan_table)
from repro.core.plan_table import _default_cost  # noqa: E402
from repro.launch.mesh import shard_devices  # noqa: E402
from repro.launch.planner import derive_q_grid, lower_buckets  # noqa: E402

ARCH, SHARDS = "qwen3-4b", 8
BUCKETS = [(b, s) for b in (2, 4) for s in (16, 24, 32)]

cfg = SMOKE_CONFIGS[ARCH]
cm = _default_cost("time")
graphs = lower_buckets(cfg, BUCKETS, "time")
qs = derive_q_grid(graphs, cm, n_q=24)
print(f"[example] {len(jax.local_devices())} devices, "
      f"{len(BUCKETS)} buckets x {len(qs)} Q points")

single = build_plan_table(cfg, BUCKETS, qs, cost=cm, graphs=graphs)
sharded = build_plan_table(
    cfg, BUCKETS, qs, cost=cm, graphs=graphs,
    sharding=QGridSharding(SHARDS, shard_devices(SHARDS)))
print(f"[example] single-host build: {single.summary()}")
print(f"[example] {SHARDS}-shard build byte-identical: "
      f"{sharded.content_digest() == single.content_digest()}")

half = build_plan_table(cfg, BUCKETS[:3], qs, cost=cm, graphs=graphs[:3])
grown = extend_plan_table(half, cfg, add_buckets=BUCKETS[3:], cost=cm)
print(f"[example] incremental {len(BUCKETS[:3])}→{len(BUCKETS)}-bucket growth "
      f"byte-identical: {grown.content_digest() == single.content_digest()}")
print(f"[example] lineage: {' → '.join(f[:10] for f in grown.lineage)}")

n = probe_plan_table(grown, cfg, k=6, cost=cm)
print(f"[example] staleness probe: {n} random cells re-validated against the "
      f"live engine — clean")
