"""Quickstart: Julienning in 60 seconds.

Builds the paper's Listing-1 application (sense → process → transmit),
partitions it under an energy bound, and executes it burst-by-burst with a
simulated power failure — the full paper pipeline on a toy app.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np

from repro.api import PartitionSpec, solve
from repro.core import (
    BurstRuntime, GraphBuilder, MemoryNVM, PAPER_FRAM_MODEL, PowerFailure,
    execute_atomic, q_min)

# 1. Declare the application: kernels with explicit data dependencies
#    (paper Listing 1, with a runnable body for each kernel).
b = GraphBuilder()
b.packet("img", 80 * 60 * 2)                    # the sensor frame
b.packet("headCount", 4, keep=True)             # the application output

b.task("sense", writes=("img",), cost=131.9e-3,
       fn=lambda inp: {"img": np.arange(4800, dtype=np.uint16) % 256})
b.task("process", reads=("img",), writes=("headCount",), cost=2.16,
       fn=lambda inp: {"headCount": np.int32((inp["img"] > 200).sum() % 7)})
b.task("transmit", reads=("headCount",), cost=0.086e-3,
       fn=lambda inp: {})
graph = b.build()

# 2. Partition under an energy-storage bound — one declarative spec through
#    the façade (objective/backends/sharding are all just spec fields)
cm = PAPER_FRAM_MODEL
print(f"Q_min (smallest feasible storage): {q_min(graph, cm) * 1e3:.1f} mJ")
part = solve(PartitionSpec(graph=graph, cost=cm, q_max=2.2)).partition()
print("partition:", part.bounds)
print(part.summary())

# 3. Execute burst-by-burst, riding through a power failure
fail_once = [True]


def flaky_power(burst, phase):
    if burst == 1 and phase == "executed" and fail_once[0]:
        fail_once[0] = False
        raise PowerFailure("capacitor drained mid-burst!")


rt = BurstRuntime(graph, part, MemoryNVM(), cost=cm, crash_hook=flaky_power)
out = rt.run_to_completion({})
ref = execute_atomic(graph, {})
assert out["headCount"] == ref["headCount"]
print(f"headCount = {out['headCount']} (matches atomic execution, "
      f"despite the injected power failure)")
