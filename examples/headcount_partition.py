"""Paper reproduction: partition + execute the head-counting application.

Reproduces Fig. 6 (Single Task vs Julienning vs Whole Application), the
design-space exploration of Figs. 7–8, and then *runs* a reduced head-count
CNN through the burst runtime with random power failures, comparing against
atomic execution.

Run:  PYTHONPATH=src python examples/headcount_partition.py
"""

import random

import numpy as np

from repro.api import PartitionSpec, solve
from repro.core import (
    BurstRuntime, MemoryNVM, PowerFailure, execute_atomic,
    q_min, single_task_partition, whole_app_partition)
from repro.core.apps.headcount import THERMAL, VISUAL, build_graph, paper_cost_model

cm = paper_cost_model()

print("=== Fig. 6: thermal head-counting @ Q_max = 132 mJ ===")
g = build_graph(THERMAL)
jl = solve(PartitionSpec(graph=g, cost=cm, q_max=132e-3,
                         backend="numpy")).partition()
st = single_task_partition(g, cm)
wa = whole_app_partition(g, cm)
print(f"Julienning:  {jl.n_bursts:5d} bursts  overhead "
      f"{100 * jl.e_overhead / jl.e_total:.3f}%  (paper: 18 bursts, 0.12%)")
print(f"Single Task: {st.n_bursts:5d} bursts  {st.transfer_bytes / 1e6:.0f} MB "
      f"transferred (paper: 5458 bursts, >437 MB)")
print(f"Whole App:   {wa.n_bursts:5d} burst   needs {wa.max_burst:.3f} J storage")
print(f"storage reduction: {100 * (1 - q_min(g, cm) / wa.max_burst):.1f}% "
      f"(paper: >94%)")

print("\n=== Figs. 7-8: design-space exploration ===")
for spec in (THERMAL, VISUAL):
    gg = build_graph(spec)
    qmn = q_min(gg, cm)
    qs = np.geomspace(qmn, gg.total_task_cost() * 1.05, 8)
    print(f"{spec.name}: Q_min = {qmn * 1e3:.2f} mJ")
    parts = solve(PartitionSpec(graph=gg, cost=cm, q_grid=tuple(qs),
                                backend="numpy")).partitions()
    for q, p in zip(qs, parts):
        if p:
            print(f"  Q={q * 1e3:8.1f} mJ → {p.n_bursts:4d} bursts, "
                  f"overhead {100 * p.e_overhead / p.e_total:6.3f}%")

print("\n=== Burst execution of the (reduced) CNN with power failures ===")
spec = THERMAL.reduced(scale=64)
g = build_graph(spec, with_fns=True, seed=3)
ref = execute_atomic(g, {})
part = solve(PartitionSpec(graph=g, cost=cm, q_max=132e-3,
                           backend="numpy")).partition()
rng = random.Random(0)
rt = BurstRuntime(g, part, MemoryNVM(), cost=cm,
                  crash_hook=lambda b, ph: (_ for _ in ()).throw(PowerFailure())
                  if rng.random() < 0.3 else None)
out = rt.run_to_completion({})
print(f"partitioned+crashy headcount = {out['headcount']}, "
      f"atomic = {ref['headcount']} → {'MATCH' if out['headcount'] == ref['headcount'] else 'MISMATCH'}")
print(f"bursts planned {part.n_bursts}, tasks re-run due to failures: "
      f"{rt.stats.tasks_run - g.n_tasks}")
