"""Generate the §Roofline table (experiments/roofline_table.md) from the
dry-run JSON records."""

import glob
import json
import os

HERE = os.path.dirname(__file__)


def main():
    recs = []
    for f in sorted(glob.glob(os.path.join(HERE, "dryrun", "*.json"))):
        recs.append(json.load(open(f)))
    ok = [r for r in recs if r.get("status") == "ok"]
    sk = [r for r in recs if r.get("status") == "skipped"]

    lines = [
        "# Roofline table — per (arch × shape × mesh)",
        "",
        f"{len(ok)} compiled cells, {len(sk)} documented skips "
        "(long_500k × full-attention archs).",
        "",
        "Terms in seconds/step/device (methodology: EXPERIMENTS.md §Roofline);",
        "`useful` = MODEL_FLOPS / (HLO_FLOPs × chips); `fit` = "
        "args+temp vs 16 GB HBM.",
        "",
        "| arch | shape | mesh | t_compute | t_memory | t_collective |"
        " dominant | useful | temp GB | fit |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for r in sorted(ok, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        t = r["roofline"]
        mem = r.get("memory", {})
        temp = mem.get("temp_size_in_bytes", 0) / 1e9
        args = mem.get("argument_size_in_bytes", 0) / 1e9
        fit = "yes" if (temp + args) <= 16.5 else "over"
        u = r.get("useful_flops_ratio")
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['t_compute']:.4f} | {t['t_memory']:.4f} "
            f"| {t['t_collective']:.4f} | {r['dominant'].replace('t_', '')} "
            f"| {u:.3f} | {temp:.1f} | {fit} |" if u else
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {t['t_compute']:.4f} | {t['t_memory']:.4f} "
            f"| {t['t_collective']:.4f} | {r['dominant'].replace('t_', '')} "
            f"| - | {temp:.1f} | {fit} |")
    lines.append("")
    lines.append("## Skipped cells")
    lines.append("")
    for r in sorted(sk, key=lambda r: (r["arch"], r["shape"], r["mesh"])):
        lines.append(f"* {r['arch']} × {r['shape']} × {r['mesh']} — {r['reason']}")
    out = os.path.join(HERE, "roofline_table.md")
    with open(out, "w") as fh:
        fh.write("\n".join(lines) + "\n")
    print(f"wrote {out}: {len(ok)} rows")


if __name__ == "__main__":
    main()
